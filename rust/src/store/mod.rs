//! S3-like object store (the paper's AWS S3 substrate).
//!
//! Usage in the paper: (a) each peer's dataset partition is uploaded to a
//! dedicated bucket of pre-batched objects the Lambda functions read;
//! (b) gradients above Amazon MQ's 100 MB message cap are stored here and
//! referenced by UUID in the queue message (§III-B.3).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::Bytes;
use std::sync::RwLock;

use crate::error::{Error, Result};

/// A pointer to a stored object, sendable through the broker in place of
/// an oversized payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ObjectRef {
    pub bucket: String,
    pub key: String,
    pub size: usize,
}

impl ObjectRef {
    /// Magic prefix distinguishing a reference message from an inline
    /// gradient payload on the broker.
    pub const WIRE_MAGIC: &'static [u8; 4] = b"S3RF";

    /// Serialize for embedding in a broker message (the paper's
    /// "send UUIDs through Amazon MQ" path).
    pub fn to_wire(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.bucket.len() + self.key.len());
        out.extend_from_slice(Self::WIRE_MAGIC);
        out.extend_from_slice(&(self.bucket.len() as u32).to_le_bytes());
        out.extend_from_slice(self.bucket.as_bytes());
        out.extend_from_slice(&(self.key.len() as u32).to_le_bytes());
        out.extend_from_slice(self.key.as_bytes());
        out.extend_from_slice(&(self.size as u64).to_le_bytes());
        out
    }

    pub fn is_wire(data: &[u8]) -> bool {
        data.len() >= 4 && &data[0..4] == Self::WIRE_MAGIC
    }

    pub fn from_wire(data: &[u8]) -> Result<Self> {
        if !Self::is_wire(data) {
            return Err(Error::Store("not an ObjectRef wire message".into()));
        }
        let mut i = 4usize;
        let take_u32 = |i: &mut usize| -> Result<usize> {
            let v = data
                .get(*i..*i + 4)
                .ok_or_else(|| Error::Store("truncated ObjectRef".into()))?;
            *i += 4;
            Ok(u32::from_le_bytes(v.try_into().unwrap()) as usize)
        };
        let blen = take_u32(&mut i)?;
        let bucket = String::from_utf8(
            data.get(i..i + blen)
                .ok_or_else(|| Error::Store("truncated ObjectRef".into()))?
                .to_vec(),
        )
        .map_err(|e| Error::Store(e.to_string()))?;
        i += blen;
        let klen = take_u32(&mut i)?;
        let key = String::from_utf8(
            data.get(i..i + klen)
                .ok_or_else(|| Error::Store("truncated ObjectRef".into()))?
                .to_vec(),
        )
        .map_err(|e| Error::Store(e.to_string()))?;
        i += klen;
        let size = data
            .get(i..i + 8)
            .ok_or_else(|| Error::Store("truncated ObjectRef".into()))?;
        Ok(Self {
            bucket,
            key,
            size: u64::from_le_bytes(size.try_into().unwrap()) as usize,
        })
    }
}

/// In-process S3: buckets of key→bytes with monotonic usage stats.
#[derive(Default)]
pub struct ObjectStore {
    buckets: RwLock<HashMap<String, HashMap<String, Bytes>>>,
    puts: AtomicU64,
    gets: AtomicU64,
    bytes_in: AtomicU64,
    key_counter: AtomicU64,
}

impl ObjectStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn create_bucket(&self, bucket: &str) {
        self.buckets.write().unwrap().entry(bucket.to_string()).or_default();
    }

    pub fn put(&self, bucket: &str, key: &str, data: Bytes) -> Result<ObjectRef> {
        let size = data.len();
        let mut buckets = self.buckets.write().unwrap();
        buckets
            .entry(bucket.to_string())
            .or_default()
            .insert(key.to_string(), data);
        self.puts.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(size as u64, Ordering::Relaxed);
        Ok(ObjectRef { bucket: bucket.to_string(), key: key.to_string(), size })
    }

    /// Store under a freshly generated UUID-ish key (the paper's
    /// large-gradient path).
    pub fn put_new(&self, bucket: &str, data: Bytes) -> Result<ObjectRef> {
        let key = self.new_key();
        self.put(bucket, &key, data)
    }

    pub fn get(&self, bucket: &str, key: &str) -> Result<Bytes> {
        self.gets.fetch_add(1, Ordering::Relaxed);
        self.buckets
            .read().unwrap()
            .get(bucket)
            .and_then(|b| b.get(key).cloned())
            .ok_or_else(|| Error::Store(format!("missing s3://{bucket}/{key}")))
    }

    pub fn get_ref(&self, r: &ObjectRef) -> Result<Bytes> {
        self.get(&r.bucket, &r.key)
    }

    pub fn delete(&self, bucket: &str, key: &str) -> Result<()> {
        let mut buckets = self.buckets.write().unwrap();
        let b = buckets
            .get_mut(bucket)
            .ok_or_else(|| Error::Store(format!("missing bucket {bucket}")))?;
        b.remove(key)
            .map(|_| ())
            .ok_or_else(|| Error::Store(format!("missing s3://{bucket}/{key}")))
    }

    pub fn list(&self, bucket: &str) -> Vec<String> {
        let mut keys: Vec<String> = self
            .buckets
            .read().unwrap()
            .get(bucket)
            .map(|b| b.keys().cloned().collect())
            .unwrap_or_default();
        keys.sort();
        keys
    }

    pub fn bucket_size(&self, bucket: &str) -> usize {
        self.buckets
            .read().unwrap()
            .get(bucket)
            .map(|b| b.values().map(|v| v.len()).sum())
            .unwrap_or(0)
    }

    /// Number of live objects in one bucket.
    pub fn object_count(&self, bucket: &str) -> usize {
        self.buckets
            .read().unwrap()
            .get(bucket)
            .map(|b| b.len())
            .unwrap_or(0)
    }

    /// Number of live objects across every bucket — the boundedness
    /// check for the per-epoch serverless sweeps.
    pub fn total_objects(&self) -> usize {
        self.buckets.read().unwrap().values().map(|b| b.len()).sum()
    }

    /// Delete every object in `bucket` (the bucket itself survives);
    /// returns how many objects were removed. Used as the per-epoch
    /// sweep of serverless scratch uploads — it must run on error
    /// paths too, where individual refs may be unknown.
    pub fn clear_bucket(&self, bucket: &str) -> usize {
        self.buckets
            .write().unwrap()
            .get_mut(bucket)
            .map(|b| {
                let n = b.len();
                b.clear();
                n
            })
            .unwrap_or(0)
    }

    /// (puts, gets, bytes written).
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.puts.load(Ordering::Relaxed),
            self.gets.load(Ordering::Relaxed),
            self.bytes_in.load(Ordering::Relaxed),
        )
    }

    /// Deterministic UUID-shaped key (process-unique).
    fn new_key(&self) -> String {
        let n = self.key_counter.fetch_add(1, Ordering::Relaxed);
        // splitmix64 the counter twice for a 128-bit looking key
        let a = splitmix64(n.wrapping_add(0x9E3779B97F4A7C15));
        let b = splitmix64(a ^ n);
        format!(
            "{:08x}-{:04x}-{:04x}-{:04x}-{:012x}",
            (a >> 32) as u32,
            (a >> 16) as u16,
            a as u16,
            (b >> 48) as u16,
            b & 0xFFFF_FFFF_FFFF
        )
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Conventional bucket name for peer `r`'s batch storage.
pub fn peer_bucket(r: usize) -> String {
    format!("peer-{r}-batches")
}

/// Bucket for oversized gradient payloads.
pub const GRADIENT_BUCKET: &str = "gradient-overflow";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let s = ObjectStore::new();
        let r = s.put("b", "k", Bytes::from_static(b"hello")).unwrap();
        assert_eq!(r.size, 5);
        assert_eq!(&s.get("b", "k").unwrap()[..], b"hello");
        assert_eq!(&s.get_ref(&r).unwrap()[..], b"hello");
    }

    #[test]
    fn get_missing_errors() {
        let s = ObjectStore::new();
        assert!(s.get("b", "k").is_err());
        s.create_bucket("b");
        assert!(s.get("b", "k").is_err());
    }

    #[test]
    fn put_new_keys_are_unique() {
        let s = ObjectStore::new();
        let r1 = s.put_new("b", Bytes::from_static(b"1")).unwrap();
        let r2 = s.put_new("b", Bytes::from_static(b"2")).unwrap();
        assert_ne!(r1.key, r2.key);
        assert_eq!(r1.key.len(), 36); // uuid shape
        assert_eq!(s.list("b").len(), 2);
    }

    #[test]
    fn delete_removes() {
        let s = ObjectStore::new();
        s.put("b", "k", Bytes::from_static(b"x")).unwrap();
        s.delete("b", "k").unwrap();
        assert!(s.get("b", "k").is_err());
        assert!(s.delete("b", "k").is_err());
    }

    #[test]
    fn bucket_accounting() {
        let s = ObjectStore::new();
        s.put("b", "k1", Bytes::from_static(b"aaaa")).unwrap();
        s.put("b", "k2", Bytes::from_static(b"bb")).unwrap();
        assert_eq!(s.bucket_size("b"), 6);
        let (puts, _gets, bytes) = s.stats();
        assert_eq!(puts, 2);
        assert_eq!(bytes, 6);
    }

    #[test]
    fn object_counts_track_deletes() {
        let s = ObjectStore::new();
        assert_eq!(s.total_objects(), 0);
        s.put("a", "k1", Bytes::from_static(b"x")).unwrap();
        s.put("b", "k2", Bytes::from_static(b"y")).unwrap();
        assert_eq!(s.object_count("a"), 1);
        assert_eq!(s.total_objects(), 2);
        s.delete("a", "k1").unwrap();
        assert_eq!(s.object_count("a"), 0);
        assert_eq!(s.total_objects(), 1);
    }

    #[test]
    fn clear_bucket_sweeps_only_that_bucket() {
        let s = ObjectStore::new();
        s.put("a", "k1", Bytes::from_static(b"x")).unwrap();
        s.put("a", "k2", Bytes::from_static(b"y")).unwrap();
        s.put("b", "k3", Bytes::from_static(b"z")).unwrap();
        assert_eq!(s.clear_bucket("a"), 2);
        assert_eq!(s.object_count("a"), 0);
        assert_eq!(s.object_count("b"), 1);
        assert_eq!(s.clear_bucket("missing"), 0);
        // the bucket survives and stays writable
        s.put("a", "k4", Bytes::from_static(b"w")).unwrap();
        assert_eq!(s.object_count("a"), 1);
    }

    #[test]
    fn object_ref_wire_roundtrip() {
        let r = ObjectRef { bucket: "b".into(), key: "k".into(), size: 9 };
        let back = ObjectRef::from_wire(&r.to_wire()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn object_ref_wire_rejects_garbage() {
        assert!(ObjectRef::from_wire(b"not a ref").is_err());
    }

    #[test]
    fn overwrite_replaces() {
        let s = ObjectStore::new();
        s.put("b", "k", Bytes::from_static(b"old")).unwrap();
        s.put("b", "k", Bytes::from_static(b"new")).unwrap();
        assert_eq!(&s.get("b", "k").unwrap()[..], b"new");
        assert_eq!(s.list("b").len(), 1);
    }
}
