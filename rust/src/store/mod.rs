//! S3-like object store (the paper's AWS S3 substrate).
//!
//! Usage in the paper: (a) each peer's dataset partition is uploaded to a
//! dedicated bucket of pre-batched objects the Lambda functions read;
//! (b) gradients above Amazon MQ's 100 MB message cap are stored here and
//! referenced by UUID in the queue message (§III-B.3).
//!
//! Objects carry a **generation** tag: [`GEN_PERSISTENT`] marks run-long
//! objects (the pre-batched dataset partitions, uploaded once before
//! training), any other value scopes the object to one epoch's scratch
//! (params, parked gradients). [`ObjectStore::sweep_generation`] reclaims
//! exactly one generation, so the per-epoch sweep cannot eat the
//! persistent batch objects — and the tag doubles as the param-version
//! id the cross-epoch offload mode keys its folds on. Under cross-epoch
//! pipelining the sweep *lags* one live generation: params v(e) stay in
//! the store while epoch e+1 is in flight, so a stale-tolerant tail
//! branch of epoch e can always re-read them.
//!
//! Identical payloads can be **deduplicated**: [`ObjectStore::put_dedup`]
//! content-hashes the bytes and answers a repeat put of the same
//! (bucket, generation, bytes) with the existing object's ref instead
//! of storing a copy — reference-counted, released per holder via
//! [`ObjectStore::release`]. Synchronous training uses this for the
//! per-epoch params upload ([`PARAMS_BUCKET`]): every peer's params
//! bytes are identical, so N peers put **one** object per epoch.
//!
//! [`DecodedCache`] sits next to the store and memoizes the
//! object-bytes → `Vec<f32>` decode of hot objects (the params object
//! every branch of an epoch reads), with a per-key in-flight guard so N
//! concurrent branches decode once, not N times. Live params versions
//! are **pinned** ([`DecodedCache::pin`]) while their epoch is in
//! flight: FIFO eviction skips pinned entries, so a small cache shared
//! by many peers (or by two overlapping epochs) can never evict a
//! params version that tail branches still need. Pins are counted per
//! holder, because deduplicated params give every peer the *same*
//! entry. A typed **packed sidecar** ([`DecodedCache::take_packed`] /
//! [`DecodedCache::put_packed`]) additionally lets the runtime check
//! its per-object PJRT input literals in and out, so batch literals are
//! packed once per object instead of once per invocation.
//!
//! ```
//! use p2pless::store::{DecodedCache, ObjectStore, GEN_PERSISTENT};
//! use p2pless::util::Bytes;
//!
//! let store = ObjectStore::new();
//! store.create_bucket("peer-0-batches");
//! // a run-long batch object and one epoch's scratch params
//! let batch = store.put_new("peer-0-batches", Bytes::from_static(b"batch")).unwrap();
//! let params = store
//!     .put_new_gen("peer-0-batches", Bytes::from_static(b"\x00\x00\x80\x3f"), 1)
//!     .unwrap();
//! assert_eq!(store.generation_of(&batch), Some(GEN_PERSISTENT));
//!
//! // the decode cache turns N reads of the params into one decode
//! let cache = DecodedCache::new(4);
//! let v1 = cache.get_or_decode(&params, &store).unwrap();
//! let v2 = cache.get_or_decode(&params, &store).unwrap();
//! assert_eq!(v1, v2);
//! assert_eq!((cache.misses(), cache.hits()), (1, 1));
//!
//! // the epoch-1 sweep reclaims the scratch, never the batch objects
//! assert_eq!(store.sweep_generation("peer-0-batches", 1), 1);
//! assert!(store.get_ref(&batch).is_ok());
//! ```

use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::harness::faults::{self, FaultPlan as ChaosPlan, StoreFault, StoreOp};
use crate::util::bytes::bytes_to_f32s;
use crate::util::retry::RetryPolicy;
use crate::util::Bytes;
use std::sync::RwLock;

use crate::error::{Error, Result};

pub mod shard;

/// Generation tag for objects that live for the whole run (the paper's
/// pre-batched dataset partitions). Never matched by an epoch sweep
/// unless explicitly requested at teardown.
pub const GEN_PERSISTENT: u64 = u64::MAX;

/// Shared bucket for deduplicated per-epoch params uploads: in
/// synchronous training every peer's params bytes are identical, so N
/// peers putting through [`ObjectStore::put_dedup`] store **one**
/// object here (reference-counted; released per peer via
/// [`ObjectStore::release`]).
pub const PARAMS_BUCKET: &str = "shared-params";

/// A pointer to a stored object, sendable through the broker in place of
/// an oversized payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ObjectRef {
    pub bucket: String,
    pub key: String,
    pub size: usize,
}

impl ObjectRef {
    /// Magic prefix distinguishing a reference message from an inline
    /// gradient payload on the broker.
    pub const WIRE_MAGIC: &'static [u8; 4] = b"S3RF";

    /// Serialize for embedding in a broker message (the paper's
    /// "send UUIDs through Amazon MQ" path).
    pub fn to_wire(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.bucket.len() + self.key.len());
        out.extend_from_slice(Self::WIRE_MAGIC);
        out.extend_from_slice(&(self.bucket.len() as u32).to_le_bytes());
        out.extend_from_slice(self.bucket.as_bytes());
        out.extend_from_slice(&(self.key.len() as u32).to_le_bytes());
        out.extend_from_slice(self.key.as_bytes());
        out.extend_from_slice(&(self.size as u64).to_le_bytes());
        out
    }

    pub fn is_wire(data: &[u8]) -> bool {
        data.len() >= 4 && &data[0..4] == Self::WIRE_MAGIC
    }

    pub fn from_wire(data: &[u8]) -> Result<Self> {
        if !Self::is_wire(data) {
            return Err(Error::Store("not an ObjectRef wire message".into()));
        }
        let mut i = 4usize;
        let take_u32 = |i: &mut usize| -> Result<usize> {
            let v = data
                .get(*i..*i + 4)
                .ok_or_else(|| Error::Store("truncated ObjectRef".into()))?;
            *i += 4;
            Ok(u32::from_le_bytes(v.try_into().unwrap()) as usize)
        };
        let blen = take_u32(&mut i)?;
        let bucket = String::from_utf8(
            data.get(i..i + blen)
                .ok_or_else(|| Error::Store("truncated ObjectRef".into()))?
                .to_vec(),
        )
        .map_err(|e| Error::Store(e.to_string()))?;
        i += blen;
        let klen = take_u32(&mut i)?;
        let key = String::from_utf8(
            data.get(i..i + klen)
                .ok_or_else(|| Error::Store("truncated ObjectRef".into()))?
                .to_vec(),
        )
        .map_err(|e| Error::Store(e.to_string()))?;
        i += klen;
        let size = data
            .get(i..i + 8)
            .ok_or_else(|| Error::Store("truncated ObjectRef".into()))?;
        i += 8;
        // a wire message is exactly the layout — trailing bytes mean a
        // corrupted or smuggled frame, not padding
        if data.len() != i {
            return Err(Error::Store(format!(
                "ObjectRef wire message has {} trailing bytes",
                data.len() - i
            )));
        }
        Ok(Self {
            bucket,
            key,
            size: u64::from_le_bytes(size.try_into().unwrap()) as usize,
        })
    }
}

/// One stored object: payload bytes, generation tag, and — for
/// deduplicated objects — a reference count plus the content hash its
/// dedup-index entry is filed under.
struct Object {
    data: Bytes,
    generation: u64,
    /// Holders of this object ([`ObjectStore::put_dedup`] increments,
    /// [`ObjectStore::release`] decrements; plain puts have one
    /// implicit holder).
    refs: usize,
    /// Content hash, for cleaning the dedup index on removal (None for
    /// non-deduplicated objects).
    content_hash: Option<u64>,
}

impl Object {
    fn plain(data: Bytes, generation: u64) -> Self {
        Self { data, generation, refs: 1, content_hash: None }
    }
}

/// FNV-1a over the object bytes — the dedup content hash. Collisions
/// are guarded by a full byte comparison before any ref is shared.
/// The shard plane's [`shard::hash_f32s`] computes the same hash over
/// an f32 view without materializing the bytes.
pub(crate) fn fnv1a64(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[derive(Default)]
struct StoreInner {
    buckets: HashMap<String, HashMap<String, Object>>,
    /// Dedup index: (bucket, generation, content hash) → key of the
    /// canonical object. Entries are removed together with their
    /// object (release to zero, sweep, delete, clear).
    dedup: HashMap<(String, u64, u64), String>,
}

/// The armed chaos hook: the resolved fault plan plus the retry policy
/// transient faults are absorbed under (`--store-retries` /
/// `--store-backoff-ms`).
#[derive(Clone)]
struct ChaosHook {
    plan: Arc<ChaosPlan>,
    retry: RetryPolicy,
}

/// In-process S3: buckets of key→object with monotonic usage stats.
///
/// When a fault plan schedules store faults, [`ObjectStore::arm_chaos`]
/// turns on the injection hook: puts and gets by a scoped peer thread
/// (see [`crate::harness::faults::FaultScope`]) can fail transiently
/// (absorbed by the configured retry policy, counted in
/// `store.retries`), sleep, or deliver corrupted bytes — the armed get
/// path verifies every read against the object's recorded content hash
/// and re-fetches on mismatch (counted in `store.corrupt_refetches`),
/// which extends the shard plane's hash verification to monolithic
/// params and `SPv1` manifests alike. Unarmed (the default), every
/// code path is byte-identical to the pre-chaos store.
#[derive(Default)]
pub struct ObjectStore {
    inner: RwLock<StoreInner>,
    puts: AtomicU64,
    gets: AtomicU64,
    bytes_in: AtomicU64,
    dedup_hits: AtomicU64,
    key_counter: AtomicU64,
    /// Injected-fault hook; `None` (default) is the untouched path.
    chaos: RwLock<Option<ChaosHook>>,
    /// Extra put/get attempts forced by injected transient errors.
    chaos_retries: AtomicU64,
    /// Corrupted reads caught by hash verification and re-fetched.
    corrupt_refetches: AtomicU64,
}

impl ObjectStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn create_bucket(&self, bucket: &str) {
        self.inner
            .write()
            .unwrap()
            .buckets
            .entry(bucket.to_string())
            .or_default();
    }

    /// Store a run-long (persistent-generation) object.
    pub fn put(&self, bucket: &str, key: &str, data: Bytes) -> Result<ObjectRef> {
        self.put_gen(bucket, key, data, GEN_PERSISTENT)
    }

    /// Store an object tagged with `generation`.
    pub fn put_gen(
        &self,
        bucket: &str,
        key: &str,
        data: Bytes,
        generation: u64,
    ) -> Result<ObjectRef> {
        let armed = self.chaos_gate(StoreOp::Put, bucket, key)?;
        let size = data.len();
        // with the chaos plane armed every object records its content
        // hash, so the verified-get path can catch corrupted reads of
        // plain objects (batches, parked gradients, warm-start params)
        // — not just the deduplicated params plane
        let object = if armed {
            let hash = fnv1a64(&data);
            Object { data, generation, refs: 1, content_hash: Some(hash) }
        } else {
            Object::plain(data, generation)
        };
        let mut inner = self.inner.write().unwrap();
        inner
            .buckets
            .entry(bucket.to_string())
            .or_default()
            .insert(key.to_string(), object);
        self.puts.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(size as u64, Ordering::Relaxed);
        Ok(ObjectRef { bucket: bucket.to_string(), key: key.to_string(), size })
    }

    /// Store under a freshly generated UUID-ish key (the paper's
    /// large-gradient path). Persistent generation.
    pub fn put_new(&self, bucket: &str, data: Bytes) -> Result<ObjectRef> {
        self.put_new_gen(bucket, data, GEN_PERSISTENT)
    }

    /// Store under a fresh key, tagged with `generation` (epoch scratch).
    pub fn put_new_gen(&self, bucket: &str, data: Bytes, generation: u64) -> Result<ObjectRef> {
        let key = self.new_key();
        self.put_gen(bucket, &key, data, generation)
    }

    /// Content-hash-deduplicated put under a fresh key: if an object
    /// with identical bytes and the same generation already lives in
    /// `bucket`, no new object is stored — the existing ref is returned
    /// with its reference count bumped (and `dedup_hits` incremented;
    /// `puts`/`bytes_in` count *stored* objects only). Every holder must
    /// [`Self::release`] its reference; the object is removed when the
    /// last one does. This is how N peers uploading identical per-epoch
    /// params bytes end up putting one object (ROADMAP follow-up from
    /// the zero-redundancy data plane).
    pub fn put_dedup(&self, bucket: &str, data: Bytes, generation: u64) -> Result<ObjectRef> {
        self.chaos_gate(StoreOp::Put, bucket, "<dedup>")?;
        let hash = fnv1a64(&data);
        let mut inner = self.inner.write().unwrap();
        let dkey = (bucket.to_string(), generation, hash);
        if let Some(key) = inner.dedup.get(&dkey).cloned() {
            if let Some(obj) = inner.buckets.get_mut(bucket).and_then(|b| b.get_mut(&key)) {
                // hash match alone is not identity — compare the bytes
                if obj.data == data {
                    obj.refs += 1;
                    let size = obj.data.len();
                    self.dedup_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(ObjectRef { bucket: bucket.to_string(), key, size });
                }
            }
            // hash collision with different bytes: fall through and
            // store separately (the collider keeps the index entry)
        }
        let key = self.new_key();
        let size = data.len();
        inner.buckets.entry(bucket.to_string()).or_default().insert(
            key.clone(),
            Object { data, generation, refs: 1, content_hash: Some(hash) },
        );
        // a hash-colliding earlier object keeps its index entry; only a
        // vacant slot is claimed
        inner.dedup.entry(dkey).or_insert_with(|| key.clone());
        self.puts.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(size as u64, Ordering::Relaxed);
        Ok(ObjectRef { bucket: bucket.to_string(), key, size })
    }

    /// Drop one reference to `r`; removes the object (and its dedup
    /// index entry) when the last reference goes. Objects stored by the
    /// plain puts carry one implicit reference, so `release` doubles as
    /// a refcount-aware delete. Returns whether the object was removed;
    /// missing objects are a no-op (a generation sweep may already have
    /// reclaimed them wholesale).
    pub fn release(&self, r: &ObjectRef) -> bool {
        let mut inner = self.inner.write().unwrap();
        let removed = {
            let Some(b) = inner.buckets.get_mut(&r.bucket) else {
                return false;
            };
            match b.get_mut(&r.key) {
                None => return false,
                Some(obj) if obj.refs > 1 => {
                    obj.refs -= 1;
                    return false;
                }
                Some(obj) => {
                    let meta = (obj.generation, obj.content_hash);
                    b.remove(&r.key);
                    meta
                }
            }
        };
        if let (generation, Some(hash)) = removed {
            let dkey = (r.bucket.clone(), generation, hash);
            // a hash-colliding sibling may own the index entry: drop it
            // only if it points at the key being removed
            if inner.dedup.get(&dkey) == Some(&r.key) {
                inner.dedup.remove(&dkey);
            }
        }
        true
    }

    /// Acquire one more reference to a live object — the shard plane's
    /// cross-generation reuse: a manifest entry pointing at a prior
    /// generation's shard holds its own reference so the older
    /// generation's retirement cannot strand it. Returns false (and
    /// acquires nothing) if the object is already gone; callers treat
    /// that as "changed" and re-upload. Dedupe can't serve this — the
    /// dedup index is generation-keyed, and reuse spans generations.
    pub fn retain(&self, r: &ObjectRef) -> bool {
        let mut inner = self.inner.write().unwrap();
        match inner.buckets.get_mut(&r.bucket).and_then(|b| b.get_mut(&r.key)) {
            Some(obj) => {
                obj.refs += 1;
                true
            }
            None => false,
        }
    }

    /// Total dedup hits: puts that were answered by an existing
    /// identical object instead of storing a new one.
    pub fn dedup_hits(&self) -> u64 {
        self.dedup_hits.load(Ordering::Relaxed)
    }

    pub fn get(&self, bucket: &str, key: &str) -> Result<Bytes> {
        let hook = self.chaos.read().unwrap().clone();
        match hook {
            None => self.get_raw(bucket, key),
            Some(h) => self.get_chaos(bucket, key, &h),
        }
    }

    /// The plain read (one S3 GET): exactly the pre-chaos `get` body.
    fn get_raw(&self, bucket: &str, key: &str) -> Result<Bytes> {
        self.gets.fetch_add(1, Ordering::Relaxed);
        self.inner
            .read().unwrap()
            .buckets
            .get(bucket)
            .and_then(|b| b.get(key).map(|o| o.data.clone()))
            .ok_or_else(|| Error::Store(format!("missing s3://{bucket}/{key}")))
    }

    /// The armed read: consumes at most the scheduled faults for the
    /// calling thread's (rank, epoch) scope, then returns a
    /// hash-verified payload. A transient error is absorbed by the
    /// retry policy (or surfaced once it is exhausted); a corrupted
    /// delivery fails verification against the object's recorded
    /// content hash and is re-fetched, counted in
    /// `store.corrupt_refetches`.
    fn get_chaos(&self, bucket: &str, key: &str, h: &ChaosHook) -> Result<Bytes> {
        let scope = faults::current_fault_scope();
        let mut transient = 0u32;
        loop {
            let fault =
                scope.and_then(|(r, e)| h.plan.take_store_fault(r, e, StoreOp::Get));
            let delivered = match fault {
                Some(StoreFault::Delay(us)) => {
                    std::thread::sleep(std::time::Duration::from_micros(us));
                    continue;
                }
                Some(StoreFault::Transient) => {
                    transient += 1;
                    if transient >= h.retry.max_attempts {
                        return Err(Error::Store(format!(
                            "injected transient get error on s3://{bucket}/{key}: \
                             {} attempts exhausted",
                            h.retry.max_attempts
                        )));
                    }
                    self.chaos_retries.fetch_add(1, Ordering::Relaxed);
                    let delay = h.retry.backoff_delay(transient);
                    if !delay.is_zero() {
                        std::thread::sleep(delay);
                    }
                    continue;
                }
                Some(StoreFault::Corrupt) => {
                    // the delivery pays a real GET, then arrives with a
                    // flipped byte
                    let clean = self.get_raw(bucket, key)?;
                    let mut bad = clean.to_vec();
                    match bad.first_mut() {
                        Some(b) => *b = !*b,
                        None => bad.push(0xFF),
                    }
                    Bytes::from(bad)
                }
                None => self.get_raw(bucket, key)?,
            };
            if self.verify_bytes(bucket, key, &delivered) {
                return Ok(delivered);
            }
            // hash mismatch: drop the poisoned payload and re-fetch
            self.corrupt_refetches.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Does `bytes` match the object's recorded content hash? Objects
    /// without a hash (stored before arming) fall back to a direct
    /// byte comparison — still a real verification, just not a cheap
    /// one. A concurrently swept object verifies trivially (there is
    /// nothing left to compare against; the caller's payload is what
    /// the store answered).
    fn verify_bytes(&self, bucket: &str, key: &str, bytes: &Bytes) -> bool {
        let inner = self.inner.read().unwrap();
        let Some(obj) = inner.buckets.get(bucket).and_then(|b| b.get(key)) else {
            return true;
        };
        match obj.content_hash {
            Some(h) => fnv1a64(bytes) == h,
            None => obj.data == *bytes,
        }
    }

    /// Arm the chaos hook: injected store faults scoped by
    /// [`crate::harness::faults::FaultScope`] fire on puts/gets under
    /// `retry`. Unarmed stores never touch any of this machinery.
    pub fn arm_chaos(&self, plan: Arc<ChaosPlan>, retry: RetryPolicy) {
        *self.chaos.write().unwrap() = Some(ChaosHook { plan, retry });
    }

    /// Is the chaos hook armed?
    pub fn chaos_armed(&self) -> bool {
        self.chaos.read().unwrap().is_some()
    }

    /// Extra put/get attempts forced by injected transient errors.
    pub fn chaos_retries(&self) -> u64 {
        self.chaos_retries.load(Ordering::Relaxed)
    }

    /// Corrupted reads caught by hash verification and re-fetched.
    pub fn corrupt_refetches(&self) -> u64 {
        self.corrupt_refetches.load(Ordering::Relaxed)
    }

    /// The put-side chaos gate: absorbs scheduled transient errors and
    /// latency under the retry policy before the put proceeds. Returns
    /// whether the chaos plane is armed (armed puts record content
    /// hashes for the verified-get path).
    fn chaos_gate(&self, op: StoreOp, bucket: &str, key: &str) -> Result<bool> {
        let hook = self.chaos.read().unwrap().clone();
        let Some(h) = hook else { return Ok(false) };
        let Some((rank, epoch)) = faults::current_fault_scope() else {
            return Ok(true);
        };
        let mut transient = 0u32;
        while let Some(fault) = h.plan.take_store_fault(rank, epoch, op) {
            match fault {
                StoreFault::Delay(us) => {
                    std::thread::sleep(std::time::Duration::from_micros(us));
                }
                StoreFault::Transient => {
                    transient += 1;
                    if transient >= h.retry.max_attempts {
                        return Err(Error::Store(format!(
                            "injected transient put error on s3://{bucket}/{key}: \
                             {} attempts exhausted",
                            h.retry.max_attempts
                        )));
                    }
                    self.chaos_retries.fetch_add(1, Ordering::Relaxed);
                    let delay = h.retry.backoff_delay(transient);
                    if !delay.is_zero() {
                        std::thread::sleep(delay);
                    }
                }
                // corruption is a read-side fault; a put site never
                // takes it (see FaultPlan::take_store_fault)
                StoreFault::Corrupt => unreachable!("corrupt fault at a put site"),
            }
        }
        Ok(true)
    }

    pub fn get_ref(&self, r: &ObjectRef) -> Result<Bytes> {
        self.get(&r.bucket, &r.key)
    }

    /// The generation an object was stored with (None if missing).
    pub fn generation_of(&self, r: &ObjectRef) -> Option<u64> {
        self.inner
            .read().unwrap()
            .buckets
            .get(&r.bucket)
            .and_then(|b| b.get(&r.key).map(|o| o.generation))
    }

    /// Unconditional delete — ignores reference counts (the store-level
    /// force path; refcounted holders use [`Self::release`]).
    pub fn delete(&self, bucket: &str, key: &str) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        let removed = {
            let b = inner
                .buckets
                .get_mut(bucket)
                .ok_or_else(|| Error::Store(format!("missing bucket {bucket}")))?;
            let obj = b
                .remove(key)
                .ok_or_else(|| Error::Store(format!("missing s3://{bucket}/{key}")))?;
            (obj.generation, obj.content_hash)
        };
        if let (generation, Some(hash)) = removed {
            let dkey = (bucket.to_string(), generation, hash);
            if inner.dedup.get(&dkey).map(String::as_str) == Some(key) {
                inner.dedup.remove(&dkey);
            }
        }
        Ok(())
    }

    pub fn list(&self, bucket: &str) -> Vec<String> {
        let mut keys: Vec<String> = self
            .inner
            .read().unwrap()
            .buckets
            .get(bucket)
            .map(|b| b.keys().cloned().collect())
            .unwrap_or_default();
        keys.sort();
        keys
    }

    pub fn bucket_size(&self, bucket: &str) -> usize {
        self.inner
            .read().unwrap()
            .buckets
            .get(bucket)
            .map(|b| b.values().map(|o| o.data.len()).sum())
            .unwrap_or(0)
    }

    /// Number of live objects in one bucket.
    pub fn object_count(&self, bucket: &str) -> usize {
        self.inner
            .read().unwrap()
            .buckets
            .get(bucket)
            .map(|b| b.len())
            .unwrap_or(0)
    }

    /// Number of live objects across every bucket — the boundedness
    /// check for the per-epoch serverless sweeps.
    pub fn total_objects(&self) -> usize {
        self.inner
            .read()
            .unwrap()
            .buckets
            .values()
            .map(|b| b.len())
            .sum()
    }

    /// Delete every object in `bucket` tagged with `generation`; returns
    /// how many were removed. The per-epoch sweep: reclaims one epoch's
    /// scratch (params, parked gradients) while the epoch-persistent
    /// batch objects survive. Runs on error paths too, where individual
    /// refs may be unknown; reference counts are ignored — a generation
    /// sweep is wholesale by contract. Pass [`GEN_PERSISTENT`] only at
    /// teardown.
    pub fn sweep_generation(&self, bucket: &str, generation: u64) -> usize {
        let mut inner = self.inner.write().unwrap();
        let StoreInner { buckets, dedup } = &mut *inner;
        buckets
            .get_mut(bucket)
            .map(|b| {
                let before = b.len();
                b.retain(|_, o| {
                    if o.generation != generation {
                        return true;
                    }
                    if let Some(hash) = o.content_hash {
                        dedup.remove(&(bucket.to_string(), generation, hash));
                    }
                    false
                });
                before - b.len()
            })
            .unwrap_or(0)
    }

    /// Delete every object in `bucket` regardless of generation (the
    /// bucket itself survives); returns how many objects were removed.
    pub fn clear_bucket(&self, bucket: &str) -> usize {
        let mut inner = self.inner.write().unwrap();
        let n = inner
            .buckets
            .get_mut(bucket)
            .map(|b| {
                let n = b.len();
                b.clear();
                n
            })
            .unwrap_or(0);
        inner.dedup.retain(|(bkt, _, _), _| bkt != bucket);
        n
    }

    /// (puts, gets, bytes written).
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.puts.load(Ordering::Relaxed),
            self.gets.load(Ordering::Relaxed),
            self.bytes_in.load(Ordering::Relaxed),
        )
    }

    /// Deterministic UUID-shaped key (process-unique).
    fn new_key(&self) -> String {
        let n = self.key_counter.fetch_add(1, Ordering::Relaxed);
        // splitmix64 the counter twice for a 128-bit looking key
        let a = splitmix64(n.wrapping_add(0x9E3779B97F4A7C15));
        let b = splitmix64(a ^ n);
        format!(
            "{:08x}-{:04x}-{:04x}-{:04x}-{:012x}",
            (a >> 32) as u32,
            (a >> 16) as u16,
            a as u16,
            (b >> 48) as u16,
            b & 0xFFFF_FFFF_FFFF
        )
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One cache entry. The value mutex is held across the decode, so every
/// concurrent reader of a missing key blocks on the *entry* (not the
/// whole cache) and exactly one of them performs the decode.
struct DecodeSlot {
    value: Mutex<Option<Arc<Vec<f32>>>>,
}

struct DecodedCacheState {
    slots: HashMap<(String, String), Arc<DecodeSlot>>,
    /// Insertion order for FIFO eviction (epoch params objects arrive
    /// one per epoch; old epochs' entries age out naturally).
    order: VecDeque<(String, String)>,
    /// Keys exempt from eviction, with a holder count: the live params
    /// generations. FIFO used to evict the previous epoch's params
    /// while tail branches still needed it when `capacity` was small —
    /// pinning is the fix. The count matters since the shared-params
    /// dedup landed: N peers pin the *same* deduplicated params entry,
    /// and the first peer to retire its generation must not drop an
    /// entry the other peers' tail branches still read.
    pinned: HashMap<(String, String), usize>,
    /// Packed-view sidecar: per-key, single-occupancy slots holding an
    /// opaque packed representation of the object (the runtime checks
    /// its PJRT batch literals in and out here, so they are packed once
    /// per object instead of once per invocation). Entries live until
    /// [`DecodedCache::invalidate`]; in practice only the run-long
    /// batch objects are ever packed, so residency is bounded by the
    /// dataset partition.
    packed: HashMap<(String, String), Box<dyn Any + Send>>,
}

impl DecodedCacheState {
    /// Drop one holder's pin on `key`; returns `true` while other
    /// holders' pins remain (the single shared copy of the per-holder
    /// pin-count protocol — both unpin and invalidate go through it).
    fn drop_pin(&mut self, key: &(String, String)) -> bool {
        if let Some(n) = self.pinned.get_mut(key) {
            *n -= 1;
            if *n > 0 {
                return true;
            }
            self.pinned.remove(key);
        }
        false
    }
}

/// Memoizes object-bytes → `Vec<f32>` decodes, keyed by (bucket, key).
///
/// The serverless gradient handler reads the *same* params object in
/// every branch of an epoch; without this cache each of the N branches
/// pays a store get plus a full f32 decode. With it, an epoch costs one
/// miss and N-1 hits — guaranteed even under concurrent branches by the
/// per-key in-flight guard. `capacity` bounds live entries (FIFO
/// eviction; pinned keys are skipped, so live params versions can
/// temporarily push residency past `capacity` rather than be evicted
/// mid-epoch); 0 disables caching entirely.
pub struct DecodedCache {
    capacity: usize,
    state: Mutex<DecodedCacheState>,
    hits: AtomicU64,
    misses: AtomicU64,
    pack_hits: AtomicU64,
    pack_misses: AtomicU64,
}

impl DecodedCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            state: Mutex::new(DecodedCacheState {
                slots: HashMap::new(),
                order: VecDeque::new(),
                pinned: HashMap::new(),
                packed: HashMap::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            pack_hits: AtomicU64::new(0),
            pack_misses: AtomicU64::new(0),
        }
    }

    /// The decoded f32 view of `r`, from cache or via one store
    /// get + decode. Failures (missing object) leave the entry empty so
    /// a later call can retry.
    pub fn get_or_decode(&self, r: &ObjectRef, store: &ObjectStore) -> Result<Arc<Vec<f32>>> {
        self.get_or_decode_with(r, store, &|bytes| Ok(bytes_to_f32s(bytes)))
    }

    /// Like [`Self::get_or_decode`] but with a caller-supplied decode —
    /// the wire plane's framed params objects decode through here. The
    /// closure runs under the entry's value lock on a miss; it may
    /// recurse into the cache for *other* keys (a delta frame resolving
    /// its base generation) but must never re-enter the same key.
    pub fn get_or_decode_with(
        &self,
        r: &ObjectRef,
        store: &ObjectStore,
        decode: &dyn Fn(&Bytes) -> Result<Vec<f32>>,
    ) -> Result<Arc<Vec<f32>>> {
        if self.capacity == 0 {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::new(decode(&store.get_ref(r)?)?));
        }
        let slot = {
            let mut st = self.state.lock().unwrap();
            let key = (r.bucket.clone(), r.key.clone());
            match st.slots.get(&key) {
                Some(s) => s.clone(),
                None => {
                    while st.order.len() >= self.capacity {
                        // evict the oldest *unpinned* entry; if every
                        // resident entry is pinned (live generations),
                        // admit over capacity instead of evicting one
                        match st.order.iter().position(|k| !st.pinned.contains_key(k)) {
                            Some(pos) => {
                                let old = st.order.remove(pos).unwrap();
                                st.slots.remove(&old);
                            }
                            None => break,
                        }
                    }
                    let s = Arc::new(DecodeSlot { value: Mutex::new(None) });
                    st.slots.insert(key.clone(), s.clone());
                    st.order.push_back(key);
                    s
                }
            }
        };
        let mut value = slot.value.lock().unwrap();
        if let Some(v) = &*value {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(v.clone());
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let decoded = Arc::new(decode(&store.get_ref(r)?)?);
        *value = Some(decoded.clone());
        Ok(decoded)
    }

    /// Exempt `r`'s entry from FIFO eviction while its generation is
    /// live (in-flight or lagged, in cross-epoch mode). Pins are
    /// counted: each holder (one per peer sharing a deduplicated params
    /// object) pins once and the entry stays exempt until every pin is
    /// dropped. Pinning a key that is not cached yet is fine — the pin
    /// takes effect when the first branch decodes it. No-op when
    /// caching is disabled.
    pub fn pin(&self, r: &ObjectRef) {
        if self.capacity == 0 {
            return;
        }
        let mut st = self.state.lock().unwrap();
        *st.pinned.entry((r.bucket.clone(), r.key.clone())).or_insert(0) += 1;
    }

    /// Drop one pin from `r`'s entry while keeping it resident (once
    /// the last pin is gone, a later insert evicts it in FIFO order).
    /// The offload retirement path doesn't need this —
    /// [`Self::invalidate`] drops a pin *and*, when it was the last,
    /// the entry in one step — but a caller that wants a formerly live
    /// generation to age out naturally instead of being dropped uses
    /// unpin.
    pub fn unpin(&self, r: &ObjectRef) {
        let mut st = self.state.lock().unwrap();
        let key = (r.bucket.clone(), r.key.clone());
        st.drop_pin(&key);
    }

    /// Keys currently pinned (live params generations).
    pub fn pinned_len(&self) -> usize {
        self.state.lock().unwrap().pinned.len()
    }

    /// Drop one holder's claim on `r`'s entry. While other holders'
    /// pins remain (peers sharing a deduplicated params object whose
    /// generations are still live), only this holder's pin is released
    /// and the entry stays resident; the last claim drops the entry,
    /// its packed sidecar, and any ghost pin (the object was swept; the
    /// key is never reused).
    pub fn invalidate(&self, r: &ObjectRef) {
        let mut st = self.state.lock().unwrap();
        let key = (r.bucket.clone(), r.key.clone());
        if st.drop_pin(&key) {
            return;
        }
        st.packed.remove(&key);
        if st.slots.remove(&key).is_some() {
            st.order.retain(|k| k != &key);
        }
    }

    /// Check the packed view of `r` out of the sidecar (removing it):
    /// the caller owns it for the duration of one execution and is
    /// expected to [`Self::put_packed`] it back. Single occupancy is
    /// the point — exactly one branch per epoch reads a given batch
    /// object, so the checkout never contends in steady state, and a
    /// rare concurrent reader (cross-epoch overlap on the same branch
    /// index) simply misses and re-packs. Typed via `Any` so the store
    /// stays ignorant of PJRT literal types. No-op (always a miss) when
    /// caching is disabled.
    pub fn take_packed<T: Any + Send>(&self, r: &ObjectRef) -> Option<Box<T>> {
        if self.capacity == 0 {
            self.pack_misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let mut st = self.state.lock().unwrap();
        let key = (r.bucket.clone(), r.key.clone());
        match st.packed.remove(&key) {
            Some(boxed) => match boxed.downcast::<T>() {
                Ok(t) => {
                    self.pack_hits.fetch_add(1, Ordering::Relaxed);
                    Some(t)
                }
                Err(boxed) => {
                    // a different packed type lives under this key:
                    // leave it for its owner
                    st.packed.insert(key, boxed);
                    self.pack_misses.fetch_add(1, Ordering::Relaxed);
                    None
                }
            },
            None => {
                self.pack_misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Check a packed view of `r` back into the sidecar (replacing
    /// whatever a concurrent re-packer may have left there). No-op when
    /// caching is disabled.
    pub fn put_packed<T: Any + Send>(&self, r: &ObjectRef, packed: Box<T>) {
        if self.capacity == 0 {
            return;
        }
        let mut st = self.state.lock().unwrap();
        st.packed.insert((r.bucket.clone(), r.key.clone()), packed);
    }

    /// Packed-sidecar checkout hits.
    pub fn pack_hits(&self) -> u64 {
        self.pack_hits.load(Ordering::Relaxed)
    }

    /// Packed-sidecar checkout misses (first packing of each object,
    /// plus every access with caching disabled).
    pub fn pack_misses(&self) -> u64 {
        self.pack_misses.load(Ordering::Relaxed)
    }

    /// Live entries (filled or in flight).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// Conventional bucket name for peer `r`'s batch storage.
pub fn peer_bucket(r: usize) -> String {
    format!("peer-{r}-batches")
}

/// Bucket for oversized gradient payloads.
pub const GRADIENT_BUCKET: &str = "gradient-overflow";

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::f32s_to_bytes;

    #[test]
    fn put_get_roundtrip() {
        let s = ObjectStore::new();
        let r = s.put("b", "k", Bytes::from_static(b"hello")).unwrap();
        assert_eq!(r.size, 5);
        assert_eq!(&s.get("b", "k").unwrap()[..], b"hello");
        assert_eq!(&s.get_ref(&r).unwrap()[..], b"hello");
    }

    #[test]
    fn get_missing_errors() {
        let s = ObjectStore::new();
        assert!(s.get("b", "k").is_err());
        s.create_bucket("b");
        assert!(s.get("b", "k").is_err());
    }

    #[test]
    fn put_new_keys_are_unique() {
        let s = ObjectStore::new();
        let r1 = s.put_new("b", Bytes::from_static(b"1")).unwrap();
        let r2 = s.put_new("b", Bytes::from_static(b"2")).unwrap();
        assert_ne!(r1.key, r2.key);
        assert_eq!(r1.key.len(), 36); // uuid shape
        assert_eq!(s.list("b").len(), 2);
    }

    #[test]
    fn delete_removes() {
        let s = ObjectStore::new();
        s.put("b", "k", Bytes::from_static(b"x")).unwrap();
        s.delete("b", "k").unwrap();
        assert!(s.get("b", "k").is_err());
        assert!(s.delete("b", "k").is_err());
    }

    #[test]
    fn bucket_accounting() {
        let s = ObjectStore::new();
        s.put("b", "k1", Bytes::from_static(b"aaaa")).unwrap();
        s.put("b", "k2", Bytes::from_static(b"bb")).unwrap();
        assert_eq!(s.bucket_size("b"), 6);
        let (puts, _gets, bytes) = s.stats();
        assert_eq!(puts, 2);
        assert_eq!(bytes, 6);
    }

    #[test]
    fn object_counts_track_deletes() {
        let s = ObjectStore::new();
        assert_eq!(s.total_objects(), 0);
        s.put("a", "k1", Bytes::from_static(b"x")).unwrap();
        s.put("b", "k2", Bytes::from_static(b"y")).unwrap();
        assert_eq!(s.object_count("a"), 1);
        assert_eq!(s.total_objects(), 2);
        s.delete("a", "k1").unwrap();
        assert_eq!(s.object_count("a"), 0);
        assert_eq!(s.total_objects(), 1);
    }

    #[test]
    fn clear_bucket_sweeps_only_that_bucket() {
        let s = ObjectStore::new();
        s.put("a", "k1", Bytes::from_static(b"x")).unwrap();
        s.put("a", "k2", Bytes::from_static(b"y")).unwrap();
        s.put("b", "k3", Bytes::from_static(b"z")).unwrap();
        assert_eq!(s.clear_bucket("a"), 2);
        assert_eq!(s.object_count("a"), 0);
        assert_eq!(s.object_count("b"), 1);
        assert_eq!(s.clear_bucket("missing"), 0);
        // the bucket survives and stays writable
        s.put("a", "k4", Bytes::from_static(b"w")).unwrap();
        assert_eq!(s.object_count("a"), 1);
    }

    #[test]
    fn generation_sweep_spares_persistent_and_other_generations() {
        let s = ObjectStore::new();
        let batch = s.put_new("b", Bytes::from_static(b"batch")).unwrap();
        let params1 = s.put_new_gen("b", Bytes::from_static(b"p1"), 1).unwrap();
        let grad1 = s.put_new_gen("b", Bytes::from_static(b"g1"), 1).unwrap();
        let params2 = s.put_new_gen("b", Bytes::from_static(b"p2"), 2).unwrap();
        assert_eq!(s.generation_of(&batch), Some(GEN_PERSISTENT));
        assert_eq!(s.generation_of(&params1), Some(1));
        assert_eq!(s.sweep_generation("b", 1), 2);
        assert!(s.get_ref(&params1).is_err());
        assert!(s.get_ref(&grad1).is_err());
        assert!(s.get_ref(&batch).is_ok(), "persistent object swept");
        assert!(s.get_ref(&params2).is_ok(), "other generation swept");
        // sweeping an empty generation / missing bucket is a no-op
        assert_eq!(s.sweep_generation("b", 1), 0);
        assert_eq!(s.sweep_generation("missing", 1), 0);
        // teardown: the persistent generation is itself sweepable
        assert_eq!(s.sweep_generation("b", GEN_PERSISTENT), 1);
        assert_eq!(s.object_count("b"), 1); // params2 remains
    }

    #[test]
    fn object_ref_wire_roundtrip() {
        let r = ObjectRef { bucket: "b".into(), key: "k".into(), size: 9 };
        let back = ObjectRef::from_wire(&r.to_wire()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn object_ref_wire_rejects_garbage() {
        assert!(ObjectRef::from_wire(b"not a ref").is_err());
    }

    #[test]
    fn object_ref_wire_rejects_trailing_garbage() {
        // regression: a wire frame longer than its decoded layout used
        // to parse successfully, silently dropping the tail
        let r = ObjectRef { bucket: "bk".into(), key: "key-1".into(), size: 7 };
        let mut wire = r.to_wire();
        assert!(ObjectRef::from_wire(&wire).is_ok());
        wire.push(0xAB);
        let err = ObjectRef::from_wire(&wire).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
        wire.extend_from_slice(b"more");
        assert!(ObjectRef::from_wire(&wire).is_err());
    }

    #[test]
    fn overwrite_replaces() {
        let s = ObjectStore::new();
        s.put("b", "k", Bytes::from_static(b"old")).unwrap();
        s.put("b", "k", Bytes::from_static(b"new")).unwrap();
        assert_eq!(&s.get("b", "k").unwrap()[..], b"new");
        assert_eq!(s.list("b").len(), 1);
    }

    #[test]
    fn decoded_cache_hits_after_first_decode() {
        let s = ObjectStore::new();
        let v = vec![1.0f32, -2.5, 3.25];
        let r = s.put_new("b", Bytes::from(f32s_to_bytes(&v))).unwrap();
        let c = DecodedCache::new(4);
        let gets_before = s.stats().1;
        assert_eq!(*c.get_or_decode(&r, &s).unwrap(), v);
        assert_eq!(*c.get_or_decode(&r, &s).unwrap(), v);
        assert_eq!(*c.get_or_decode(&r, &s).unwrap(), v);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.hits(), 2);
        // the store was touched exactly once
        assert_eq!(s.stats().1 - gets_before, 1);
    }

    #[test]
    fn decoded_cache_capacity_evicts_fifo() {
        let s = ObjectStore::new();
        let refs: Vec<ObjectRef> = (0..3)
            .map(|i| s.put_new("b", Bytes::from(f32s_to_bytes(&[i as f32]))).unwrap())
            .collect();
        let c = DecodedCache::new(2);
        c.get_or_decode(&refs[0], &s).unwrap();
        c.get_or_decode(&refs[1], &s).unwrap();
        assert_eq!(c.len(), 2);
        c.get_or_decode(&refs[2], &s).unwrap(); // evicts refs[0]
        assert_eq!(c.len(), 2);
        c.get_or_decode(&refs[0], &s).unwrap(); // re-decoded
        assert_eq!(c.misses(), 4);
        assert_eq!(c.hits(), 0);
    }

    #[test]
    fn decoded_cache_pin_survives_eviction() {
        // regression: with a small capacity, inserting the next epoch's
        // params used to evict the previous epoch's entry while tail
        // branches still needed it — pinned entries must survive FIFO
        let s = ObjectStore::new();
        let refs: Vec<ObjectRef> = (0..3)
            .map(|i| s.put_new("b", Bytes::from(f32s_to_bytes(&[i as f32]))).unwrap())
            .collect();
        let c = DecodedCache::new(1);
        c.pin(&refs[0]);
        c.get_or_decode(&refs[0], &s).unwrap();
        // a new insert cannot evict the pinned live generation: the
        // cache admits over capacity instead
        c.get_or_decode(&refs[1], &s).unwrap();
        assert_eq!(c.len(), 2);
        c.get_or_decode(&refs[0], &s).unwrap();
        assert_eq!(c.hits(), 1, "pinned entry must still be resident");
        assert_eq!(c.misses(), 2);
        // unpinned, it ages out in FIFO order like any other entry
        c.unpin(&refs[0]);
        assert_eq!(c.pinned_len(), 0);
        c.get_or_decode(&refs[2], &s).unwrap();
        c.get_or_decode(&refs[0], &s).unwrap();
        assert_eq!(c.misses(), 4, "unpinned entry was evicted and re-decoded");
    }

    #[test]
    fn decoded_cache_pin_before_first_decode_and_invalidate_clears_pin() {
        let s = ObjectStore::new();
        let a = s.put_new("b", Bytes::from(f32s_to_bytes(&[1.0]))).unwrap();
        let b = s.put_new("b", Bytes::from(f32s_to_bytes(&[2.0]))).unwrap();
        let c = DecodedCache::new(1);
        // pinning an uncached key marks it ahead of the first decode
        c.pin(&a);
        assert_eq!(c.pinned_len(), 1);
        c.get_or_decode(&a, &s).unwrap();
        c.get_or_decode(&b, &s).unwrap();
        assert_eq!(*c.get_or_decode(&a, &s).unwrap(), vec![1.0]);
        assert_eq!(c.hits(), 1);
        // invalidate (the sweep path) drops both the entry and the pin
        c.invalidate(&a);
        assert_eq!(c.pinned_len(), 0);
        // disabled cache: pin is a no-op, nothing is retained
        let off = DecodedCache::new(0);
        off.pin(&a);
        assert_eq!(off.pinned_len(), 0);
    }

    #[test]
    fn decoded_cache_invalidate_and_disabled_mode() {
        let s = ObjectStore::new();
        let r = s.put_new("b", Bytes::from(f32s_to_bytes(&[4.0]))).unwrap();
        let c = DecodedCache::new(4);
        c.get_or_decode(&r, &s).unwrap();
        c.invalidate(&r);
        assert!(c.is_empty());
        c.get_or_decode(&r, &s).unwrap();
        assert_eq!(c.misses(), 2);
        // capacity 0 = disabled: every call decodes, nothing is retained
        let off = DecodedCache::new(0);
        off.get_or_decode(&r, &s).unwrap();
        off.get_or_decode(&r, &s).unwrap();
        assert_eq!(off.misses(), 2);
        assert_eq!(off.hits(), 0);
        assert!(off.is_empty());
    }

    #[test]
    fn put_dedup_shares_identical_bytes_within_a_generation() {
        let s = ObjectStore::new();
        let bytes = Bytes::from_static(b"params-v1");
        let r0 = s.put_dedup("shared", bytes.clone(), 1).unwrap();
        let r1 = s.put_dedup("shared", bytes.clone(), 1).unwrap();
        // one object, one put, one dedup hit — N peers put 1 object
        assert_eq!(r0, r1);
        assert_eq!(s.object_count("shared"), 1);
        assert_eq!(s.stats().0, 1, "a dedup hit must not count as a put");
        assert_eq!(s.dedup_hits(), 1);
        // a different generation of the same bytes is a separate object
        let r2 = s.put_dedup("shared", bytes.clone(), 2).unwrap();
        assert_ne!(r0.key, r2.key);
        assert_eq!(s.object_count("shared"), 2);
        // different bytes in the same generation too
        let r3 = s.put_dedup("shared", Bytes::from_static(b"params-v1'"), 1).unwrap();
        assert_ne!(r0.key, r3.key);
        assert_eq!(s.dedup_hits(), 1);
    }

    #[test]
    fn release_removes_on_last_reference_only() {
        let s = ObjectStore::new();
        let bytes = Bytes::from_static(b"shared-params");
        let r = s.put_dedup("shared", bytes.clone(), 3).unwrap();
        s.put_dedup("shared", bytes.clone(), 3).unwrap(); // second holder
        assert!(!s.release(&r), "first release must keep the object");
        assert!(s.get_ref(&r).is_ok());
        assert!(s.release(&r), "last release removes it");
        assert!(s.get_ref(&r).is_err());
        // the dedup index entry went with it: the same bytes store anew
        let r2 = s.put_dedup("shared", bytes, 3).unwrap();
        assert_ne!(r.key, r2.key, "stale index entry must not resurrect a freed key");
        assert!(s.get_ref(&r2).is_ok());
        // releasing a missing object is a no-op (sweeps run wholesale)
        assert!(!s.release(&r));
        // plain puts carry one implicit reference
        let p = s.put_new("b", Bytes::from_static(b"x")).unwrap();
        assert!(s.release(&p));
        assert!(s.get_ref(&p).is_err());
    }

    #[test]
    fn retain_acquires_a_reference_and_reports_dead_objects() {
        let s = ObjectStore::new();
        let r = s.put_dedup("shared", Bytes::from_static(b"shard-0"), 1).unwrap();
        assert!(s.retain(&r), "live object must be retainable");
        // two references now: one from put, one from retain
        assert!(!s.release(&r), "retained object survives the original release");
        assert!(s.get_ref(&r).is_ok());
        assert!(s.release(&r), "last release removes it");
        assert!(s.get_ref(&r).is_err());
        // retaining a dead object acquires nothing
        assert!(!s.retain(&r), "dead object must not be retainable");
        assert!(!s.release(&r));
    }

    #[test]
    fn generation_sweep_purges_dedup_index() {
        let s = ObjectStore::new();
        let bytes = Bytes::from_static(b"params");
        let r = s.put_dedup("shared", bytes.clone(), 5).unwrap();
        s.put_dedup("shared", bytes.clone(), 5).unwrap();
        assert_eq!(s.sweep_generation("shared", 5), 1);
        // the sweep is wholesale (refcounts ignored) and the index is
        // clean: identical bytes after the sweep are a fresh object,
        // not a dangling ref
        let r2 = s.put_dedup("shared", bytes, 5).unwrap();
        assert_ne!(r.key, r2.key);
        assert!(s.get_ref(&r2).is_ok());
        assert_eq!(s.dedup_hits(), 1);
    }

    #[test]
    fn decoded_cache_pins_are_counted_per_holder() {
        // the shared-params shape: two peers pin the same deduplicated
        // entry; the first peer's retirement (invalidate) must leave
        // the entry resident for the second peer's tail branches
        let s = ObjectStore::new();
        let r = s.put_new("b", Bytes::from(f32s_to_bytes(&[1.0, 2.0]))).unwrap();
        let c = DecodedCache::new(4);
        c.pin(&r);
        c.pin(&r);
        assert_eq!(c.pinned_len(), 1, "one key, two holders");
        c.get_or_decode(&r, &s).unwrap();
        c.invalidate(&r); // peer 0 retires
        assert_eq!(c.pinned_len(), 1);
        assert_eq!(*c.get_or_decode(&r, &s).unwrap(), vec![1.0, 2.0]);
        assert_eq!(c.hits(), 1, "entry must survive the first holder's retirement");
        c.invalidate(&r); // peer 1 retires: entry drops
        assert_eq!(c.pinned_len(), 0);
        assert!(c.is_empty());
    }

    #[test]
    fn packed_sidecar_checks_out_and_back_in() {
        let s = ObjectStore::new();
        let r = s.put_new("b", Bytes::from_static(b"batch")).unwrap();
        let c = DecodedCache::new(4);
        // nothing packed yet: miss
        assert!(c.take_packed::<Vec<u8>>(&r).is_none());
        assert_eq!((c.pack_hits(), c.pack_misses()), (0, 1));
        // check in, check out: hit, and the sidecar is empty again
        c.put_packed(&r, Box::new(vec![7u8, 8, 9]));
        let got = c.take_packed::<Vec<u8>>(&r).expect("checked-in view");
        assert_eq!(*got, vec![7, 8, 9]);
        assert_eq!((c.pack_hits(), c.pack_misses()), (1, 1));
        assert!(c.take_packed::<Vec<u8>>(&r).is_none(), "single occupancy");
        // a mismatched type stays put for its owner
        c.put_packed(&r, Box::new(vec![1u8]));
        assert!(c.take_packed::<String>(&r).is_none());
        assert!(c.take_packed::<Vec<u8>>(&r).is_some());
        // invalidate drops the sidecar entry with the rest
        c.put_packed(&r, Box::new(vec![2u8]));
        c.invalidate(&r);
        assert!(c.take_packed::<Vec<u8>>(&r).is_none());
        // disabled cache: put is a no-op, take always misses
        let off = DecodedCache::new(0);
        off.put_packed(&r, Box::new(vec![3u8]));
        assert!(off.take_packed::<Vec<u8>>(&r).is_none());
        assert_eq!(off.pack_hits(), 0);
    }

    #[test]
    fn decoded_cache_miss_on_absent_object_can_retry() {
        let s = ObjectStore::new();
        let c = DecodedCache::new(4);
        let r = ObjectRef { bucket: "b".into(), key: "nope".into(), size: 4 };
        assert!(c.get_or_decode(&r, &s).is_err());
        // the object appears later under the same key: the empty slot
        // must not pin the failure
        s.put("b", "nope", Bytes::from(f32s_to_bytes(&[9.0]))).unwrap();
        assert_eq!(*c.get_or_decode(&r, &s).unwrap(), vec![9.0]);
    }
}
