//! Sharded params manifest (`SPv1`) — the big-model data plane.
//!
//! The monolithic plane ships the *whole* params object to every
//! gradient Lambda each epoch; at production model sizes that re-uploads
//! and re-decodes megabytes even when only a few layers changed. This
//! module splits the packed params into contiguous **shards** (an even
//! `--params-sharding N` split, or one shard per layer from the AOT
//! manifest's `params_spec`), content-hashes each shard, and describes
//! one generation's params as a small **manifest object**: shard
//! id/kind/bytes/hash/object-ref per entry (the schema shape of
//! `manifest-core` in the PB-AI sharder, see SNIPPETS.md snippet 1).
//!
//! Per generation, a peer uploads the manifest plus **only the shards
//! whose content hash changed** since its previous upload; an unchanged
//! shard's entry carries the *prior* generation's object ref, kept alive
//! by an extra store reference ([`ObjectStore::retain`]) that this
//! holder releases when the generation retires — so the reuse composes
//! with the refcounted shared-params dedupe and the lagged sweep without
//! any new lifecycle. The handler side resolves the manifest through the
//! [`DecodedCache`](super::DecodedCache) per shard, so a generation
//! decodes each *changed* shard exactly once cluster-wide, and verifies
//! every shard's content hash before reassembly.
//!
//! Everything here is store-level plumbing: the wire plane's per-shard
//! delta framing stays in `compress::wire` (the encode closure passed to
//! [`upload_sharded`] is where the offload plugs it in), and the
//! dispatch lifecycle stays in `coordinator::serverless`.
//!
//! ## Manifest wire format (magic `SPv1`)
//!
//! ```text
//! "SPv1" | u32 shard_count LE | u64 total_elems LE | per shard:
//!   u32 id | u8 kind | u64 elems | u64 hash | u64 generation
//!   | u32 ref_len | ObjectRef wire (ref_len bytes)
//! ```
//!
//! `hash` is FNV-1a over the shard's *receiver-side* f32 bytes (the
//! reconstruction a decoder produces — identical to the true params
//! under lossless codecs, the mirrored reconstruction under lossy delta
//! frames), so the handler can verify what it actually decoded.
//! Parsing is strict: bad magic, unsupported version, truncation, id or
//! element-count mismatches, and trailing bytes are all actionable
//! [`Error`]s, never a panic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::util::Bytes;

use super::{ObjectRef, ObjectStore};

/// Magic prefix of an `SPv1` shard-manifest object.
pub const SHARD_MAGIC: &[u8; 4] = b"SPv1";

/// Shard payload kind: raw little-endian f32 bytes.
pub const SHARD_KIND_RAW: u8 = 0;
/// Shard payload kind: a wire-plane `WPv1` frame (full or delta).
pub const SHARD_KIND_WIRE: u8 = 1;

/// FNV-1a over the little-endian byte view of an f32 slice — the shard
/// content hash. Identical to the store's dedup hash over
/// `f32s_to_bytes(vals)`, without materializing the byte vector.
pub fn hash_f32s(vals: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for v in vals {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// The `--params-sharding` knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardSpec {
    /// Monolithic params object — today's plane, byte for byte.
    Off,
    /// Split the packed params into `n` contiguous near-equal shards.
    Count(usize),
    /// One shard per layer, sizes from the AOT manifest's `params_spec`.
    Layer,
}

impl ShardSpec {
    /// Parse `"off"`, `"layer"`, or a shard count.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(Self::Off),
            "layer" => Ok(Self::Layer),
            _ => {
                let n: usize = s.parse().map_err(|_| {
                    Error::Config(format!(
                        "bad params_sharding {s:?} (want off, layer, or a shard count)"
                    ))
                })?;
                if n == 0 {
                    return Err(Error::Config(
                        "params_sharding count must be >= 1 (use \"off\" to disable)".into(),
                    ));
                }
                Ok(Self::Count(n))
            }
        }
    }

    pub fn on(&self) -> bool {
        !matches!(self, Self::Off)
    }
}

/// Resolve a spec to the contiguous `(offset, elems)` shard ranges over
/// a `total_elems`-element params vector. `layer_sizes` comes from the
/// AOT manifest's `params_spec` and is only consulted in layer mode.
pub fn resolve_layout(
    spec: &ShardSpec,
    total_elems: usize,
    layer_sizes: &[usize],
) -> Result<Vec<(usize, usize)>> {
    if total_elems == 0 {
        return Err(Error::Config(
            "params_sharding cannot shard an empty params vector".into(),
        ));
    }
    match spec {
        ShardSpec::Off => Ok(Vec::new()),
        ShardSpec::Count(n) => {
            // more shards than elements would create empty shards:
            // clamp instead of erroring so tiny test models still run
            let n = (*n).min(total_elems);
            let base = total_elems / n;
            let extra = total_elems % n;
            let mut out = Vec::with_capacity(n);
            let mut off = 0;
            for i in 0..n {
                let len = base + usize::from(i < extra);
                out.push((off, len));
                off += len;
            }
            Ok(out)
        }
        ShardSpec::Layer => {
            if layer_sizes.is_empty() {
                return Err(Error::Config(
                    "params_sharding layer needs the AOT manifest's params_spec — \
                     rebuild artifacts with a compiler that emits per-layer \
                     shapes, or use a numeric shard count"
                        .into(),
                ));
            }
            let mut out = Vec::with_capacity(layer_sizes.len());
            let mut off = 0;
            for (i, &len) in layer_sizes.iter().enumerate() {
                if len == 0 {
                    return Err(Error::Config(format!(
                        "params_sharding layer: params_spec layer {i} has zero elements"
                    )));
                }
                out.push((off, len));
                off += len;
            }
            if off != total_elems {
                return Err(Error::Config(format!(
                    "params_sharding layer: params_spec covers {off} elements \
                     but the model has {total_elems}"
                )));
            }
            Ok(out)
        }
    }
}

/// One manifest entry: which shard, how it is encoded, how many f32
/// elements it reassembles to, the content hash of its decoded view,
/// the generation its object was stored under (older than the
/// manifest's for a reused shard), and the object itself.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardEntry {
    pub id: u32,
    pub kind: u8,
    pub elems: usize,
    pub hash: u64,
    pub generation: u64,
    pub object: ObjectRef,
}

/// One generation's params described as shards (`SPv1`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardManifest {
    pub total_elems: usize,
    pub shards: Vec<ShardEntry>,
}

impl ShardManifest {
    pub fn is_wire(data: &[u8]) -> bool {
        data.len() >= 4 && &data[0..4] == SHARD_MAGIC
    }

    pub fn to_wire(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.shards.len() * 48);
        out.extend_from_slice(SHARD_MAGIC);
        out.extend_from_slice(&(self.shards.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.total_elems as u64).to_le_bytes());
        for s in &self.shards {
            let ref_wire = s.object.to_wire();
            out.extend_from_slice(&s.id.to_le_bytes());
            out.push(s.kind);
            out.extend_from_slice(&(s.elems as u64).to_le_bytes());
            out.extend_from_slice(&s.hash.to_le_bytes());
            out.extend_from_slice(&s.generation.to_le_bytes());
            out.extend_from_slice(&(ref_wire.len() as u32).to_le_bytes());
            out.extend_from_slice(&ref_wire);
        }
        out
    }

    /// Strict parse: the buffer must be exactly one well-formed `SPv1`
    /// manifest — truncation, trailing bytes, out-of-order ids and a
    /// header/entry element-count mismatch are all rejected.
    pub fn from_wire(data: &[u8]) -> Result<Self> {
        if data.len() < 4 || data[0..3] != SHARD_MAGIC[0..3] {
            return Err(Error::Store("not an SPv1 shard manifest".into()));
        }
        if data[3] != SHARD_MAGIC[3] {
            return Err(Error::Store(format!(
                "unsupported shard manifest version {:?} (this runtime \
                 understands SPv1)",
                char::from(data[3])
            )));
        }
        let mut i = 4usize;
        let take = |i: &mut usize, n: usize| -> Result<&[u8]> {
            let v = data
                .get(*i..*i + n)
                .ok_or_else(|| Error::Store("truncated SPv1 shard manifest".into()))?;
            *i += n;
            Ok(v)
        };
        let count = u32::from_le_bytes(take(&mut i, 4)?.try_into().unwrap()) as usize;
        let total_elems = u64::from_le_bytes(take(&mut i, 8)?.try_into().unwrap()) as usize;
        let mut shards = Vec::with_capacity(count.min(4096));
        let mut covered = 0usize;
        for idx in 0..count {
            let id = u32::from_le_bytes(take(&mut i, 4)?.try_into().unwrap());
            if id as usize != idx {
                return Err(Error::Store(format!(
                    "SPv1 shard manifest: entry {idx} carries id {id}"
                )));
            }
            let kind = take(&mut i, 1)?[0];
            let elems = u64::from_le_bytes(take(&mut i, 8)?.try_into().unwrap()) as usize;
            let hash = u64::from_le_bytes(take(&mut i, 8)?.try_into().unwrap());
            let generation = u64::from_le_bytes(take(&mut i, 8)?.try_into().unwrap());
            let ref_len = u32::from_le_bytes(take(&mut i, 4)?.try_into().unwrap()) as usize;
            let object = ObjectRef::from_wire(take(&mut i, ref_len)?)?;
            covered += elems;
            shards.push(ShardEntry { id, kind, elems, hash, generation, object });
        }
        if i != data.len() {
            return Err(Error::Store(format!(
                "SPv1 shard manifest has {} trailing bytes",
                data.len() - i
            )));
        }
        if covered != total_elems {
            return Err(Error::Store(format!(
                "SPv1 shard manifest: entries cover {covered} elements but the \
                 header claims {total_elems}"
            )));
        }
        Ok(Self { total_elems, shards })
    }
}

/// Verify one decoded shard against its manifest entry: the element
/// count and the content hash must both match, or the decode chain
/// delivered the wrong (or corrupted) bytes.
pub fn verify_shard(entry: &ShardEntry, decoded: &[f32]) -> Result<()> {
    if decoded.len() != entry.elems {
        return Err(Error::Store(format!(
            "shard {} decoded to {} elements, manifest says {}",
            entry.id,
            decoded.len(),
            entry.elems
        )));
    }
    let h = hash_f32s(decoded);
    if h != entry.hash {
        return Err(Error::Store(format!(
            "shard {} content hash mismatch: decoded {h:#018x}, manifest \
             says {:#018x}",
            entry.id, entry.hash
        )));
    }
    Ok(())
}

/// Cluster-shared shard-plane state: the resolved layout plus the
/// `shard.*` counters the trainer exports (all zero with the plane
/// off, like the wire plane's).
pub struct ShardPlane {
    spec: ShardSpec,
    /// Contiguous `(offset, elems)` ranges; empty when the plane is off.
    layout: Vec<(usize, usize)>,
    total: AtomicU64,
    changed: AtomicU64,
    reused: AtomicU64,
    bytes_saved: AtomicU64,
}

impl ShardPlane {
    pub fn new(spec: ShardSpec, total_elems: usize, layer_sizes: &[usize]) -> Result<Self> {
        let layout = if spec.on() {
            resolve_layout(&spec, total_elems, layer_sizes)?
        } else {
            Vec::new()
        };
        Ok(Self {
            spec,
            layout,
            total: AtomicU64::new(0),
            changed: AtomicU64::new(0),
            reused: AtomicU64::new(0),
            bytes_saved: AtomicU64::new(0),
        })
    }

    /// A fully disabled plane: the monolithic params object, byte for
    /// byte.
    pub fn off() -> Self {
        Self::new(ShardSpec::Off, 1, &[]).expect("off plane is infallible")
    }

    pub fn on(&self) -> bool {
        self.spec.on()
    }

    pub fn spec(&self) -> ShardSpec {
        self.spec
    }

    pub fn layout(&self) -> &[(usize, usize)] {
        &self.layout
    }

    pub fn shard_count(&self) -> usize {
        self.layout.len()
    }

    /// Shard slots considered across every upload (uploads × shards).
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Shards whose content hash changed and were (re-)encoded.
    pub fn changed(&self) -> u64 {
        self.changed.load(Ordering::Relaxed)
    }

    /// Shards reused from a prior generation (entry carries the old
    /// object, retained instead of re-uploaded).
    pub fn reused(&self) -> u64 {
        self.reused.load(Ordering::Relaxed)
    }

    /// On-wire bytes the reuse avoided shipping (the reused objects'
    /// stored sizes).
    pub fn bytes_saved(&self) -> u64 {
        self.bytes_saved.load(Ordering::Relaxed)
    }
}

/// The previous upload of one shard, per holder: the content hash of
/// the *true* params slice (change detection), the hash of its
/// receiver-side reconstruction (what the manifest advertises), and the
/// stored object + generation a reuse re-references.
struct PrevShard {
    true_hash: u64,
    wire_hash: u64,
    object: ObjectRef,
    generation: u64,
}

/// One holder's (peer offload's) per-shard upload history.
pub struct ShardState {
    prev: Mutex<Vec<Option<PrevShard>>>,
}

impl ShardState {
    pub fn new(shards: usize) -> Self {
        Self { prev: Mutex::new((0..shards).map(|_| None).collect()) }
    }
}

/// Outcome of one sharded params upload: the stored manifest, the shard
/// object references this holder now owns (one per shard — freshly put
/// or retained), and which shards were reused (so the caller can re-key
/// per-shard delta chains).
pub struct ShardUpload {
    pub manifest: ObjectRef,
    pub shards: Vec<ObjectRef>,
    pub reused: Vec<bool>,
}

/// Upload params v(`generation`) as shards + manifest into `bucket`.
///
/// Per shard: hash the true params slice; if it matches this holder's
/// previous upload *and* the old object is still alive
/// ([`ObjectStore::retain`] acquires this holder's reference), the
/// manifest entry reuses the prior generation's object — nothing is
/// encoded or shipped. Otherwise `encode_put(shard_idx, slice)` encodes
/// and stores the shard (through `put_dedup`, so synchronous peers
/// still store one object per shard per generation) and returns the new
/// ref plus the receiver-side reconstruction the manifest hash is
/// computed over. The manifest itself is `put_dedup`'d last — its bytes
/// are rank-independent, so N peers store one manifest per generation.
///
/// A steady-state epoch touching k of L shards therefore puts exactly
/// k shard objects + 1 manifest (cluster-wide, after dedupe).
///
/// On error every reference acquired so far is released — a failed
/// upload leaks nothing into the store.
#[allow(clippy::too_many_arguments)]
pub fn upload_sharded<E>(
    plane: &ShardPlane,
    state: &ShardState,
    store: &ObjectStore,
    bucket: &str,
    params: &[f32],
    generation: u64,
    kind: u8,
    mut encode_put: E,
) -> Result<ShardUpload>
where
    E: FnMut(usize, &[f32]) -> Result<(ObjectRef, Vec<f32>)>,
{
    let layout = plane.layout();
    if layout.is_empty() {
        return Err(Error::Store(
            "upload_sharded called with the shard plane off".into(),
        ));
    }
    let covered: usize = layout.iter().map(|&(_, n)| n).sum();
    if covered != params.len() {
        return Err(Error::Store(format!(
            "shard layout covers {covered} elements but params have {}",
            params.len()
        )));
    }
    let mut prev = state.prev.lock().unwrap();
    if prev.len() != layout.len() {
        return Err(Error::Store(format!(
            "shard state tracks {} shards but the layout has {}",
            prev.len(),
            layout.len()
        )));
    }
    let mut entries = Vec::with_capacity(layout.len());
    let mut shards: Vec<ObjectRef> = Vec::with_capacity(layout.len());
    let mut reused_flags = vec![false; layout.len()];
    let (mut changed, mut reused, mut saved) = (0u64, 0u64, 0u64);
    let outcome = (|| -> Result<()> {
        for (i, &(off, n)) in layout.iter().enumerate() {
            let slice = &params[off..off + n];
            let true_hash = hash_f32s(slice);
            // unchanged since this holder's previous upload *and* the
            // object still resolvable: retain acquires our reference
            // atomically, so a concurrent release cannot strand the
            // manifest entry on a dead object
            let reuse = matches!(
                &prev[i],
                Some(p) if p.true_hash == true_hash && store.retain(&p.object)
            );
            if reuse {
                let p = prev[i].as_ref().unwrap();
                entries.push(ShardEntry {
                    id: i as u32,
                    kind,
                    elems: n,
                    hash: p.wire_hash,
                    generation: p.generation,
                    object: p.object.clone(),
                });
                shards.push(p.object.clone());
                reused_flags[i] = true;
                reused += 1;
                saved += p.object.size as u64;
            } else {
                let (object, recon) = encode_put(i, slice)?;
                if recon.len() != n {
                    return Err(Error::Store(format!(
                        "shard {i} encoder reconstructed {} elements, expected {n}",
                        recon.len()
                    )));
                }
                let wire_hash = hash_f32s(&recon);
                entries.push(ShardEntry {
                    id: i as u32,
                    kind,
                    elems: n,
                    hash: wire_hash,
                    generation,
                    object: object.clone(),
                });
                prev[i] = Some(PrevShard {
                    true_hash,
                    wire_hash,
                    object: object.clone(),
                    generation,
                });
                shards.push(object);
                changed += 1;
            }
        }
        Ok(())
    })();
    drop(prev);
    if let Err(e) = outcome {
        for r in &shards {
            store.release(r);
        }
        return Err(e);
    }
    plane.total.fetch_add(layout.len() as u64, Ordering::Relaxed);
    plane.changed.fetch_add(changed, Ordering::Relaxed);
    plane.reused.fetch_add(reused, Ordering::Relaxed);
    plane.bytes_saved.fetch_add(saved, Ordering::Relaxed);
    let manifest = ShardManifest { total_elems: params.len(), shards: entries };
    let manifest_ref =
        match store.put_dedup(bucket, Bytes::from(manifest.to_wire()), generation) {
            Ok(r) => r,
            Err(e) => {
                for r in &shards {
                    store.release(r);
                }
                return Err(e);
            }
        };
    Ok(ShardUpload { manifest: manifest_ref, shards, reused: reused_flags })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{fnv1a64, PARAMS_BUCKET};
    use crate::util::bytes::f32s_to_bytes;

    fn plane(n: usize, total: usize) -> ShardPlane {
        ShardPlane::new(ShardSpec::Count(n), total, &[]).unwrap()
    }

    /// Raw-f32 encode closure: what the offload passes with the wire
    /// plane off.
    fn raw_put<'a>(
        store: &'a ObjectStore,
        generation: u64,
    ) -> impl FnMut(usize, &[f32]) -> Result<(ObjectRef, Vec<f32>)> + 'a {
        move |_, slice| {
            let r = store.put_dedup(
                PARAMS_BUCKET,
                Bytes::from(f32s_to_bytes(slice)),
                generation,
            )?;
            Ok((r, slice.to_vec()))
        }
    }

    #[test]
    fn spec_parses_and_rejects() {
        assert_eq!(ShardSpec::parse("off").unwrap(), ShardSpec::Off);
        assert_eq!(ShardSpec::parse("layer").unwrap(), ShardSpec::Layer);
        assert_eq!(ShardSpec::parse("8").unwrap(), ShardSpec::Count(8));
        assert!(!ShardSpec::Off.on());
        assert!(ShardSpec::Layer.on());
        let err = ShardSpec::parse("banana").unwrap_err().to_string();
        assert!(err.contains("params_sharding"), "{err}");
        let err = ShardSpec::parse("0").unwrap_err().to_string();
        assert!(err.contains("params_sharding"), "{err}");
    }

    #[test]
    fn count_layout_splits_evenly_with_remainder_up_front() {
        let l = resolve_layout(&ShardSpec::Count(3), 10, &[]).unwrap();
        assert_eq!(l, vec![(0, 4), (4, 3), (7, 3)]);
        // more shards than elements clamps instead of creating empties
        let l = resolve_layout(&ShardSpec::Count(10), 3, &[]).unwrap();
        assert_eq!(l, vec![(0, 1), (1, 1), (2, 1)]);
        assert!(resolve_layout(&ShardSpec::Count(2), 0, &[]).is_err());
    }

    #[test]
    fn layer_layout_follows_spec_and_rejects_mismatch() {
        let l = resolve_layout(&ShardSpec::Layer, 10, &[4, 5, 1]).unwrap();
        assert_eq!(l, vec![(0, 4), (4, 5), (9, 1)]);
        let err = resolve_layout(&ShardSpec::Layer, 10, &[]).unwrap_err().to_string();
        assert!(err.contains("params_spec"), "{err}");
        let err = resolve_layout(&ShardSpec::Layer, 10, &[4, 5]).unwrap_err().to_string();
        assert!(err.contains("10"), "{err}");
        assert!(resolve_layout(&ShardSpec::Layer, 4, &[4, 0]).is_err());
    }

    #[test]
    fn hash_matches_store_dedup_hash() {
        let v: Vec<f32> = (0..257).map(|i| (i as f32) * 0.5 - 3.0).collect();
        assert_eq!(hash_f32s(&v), fnv1a64(&f32s_to_bytes(&v)));
        assert_eq!(hash_f32s(&[]), fnv1a64(&[]));
        // -0.0 and 0.0 hash differently: the hash is over the bit view,
        // exactly like the store's byte-level dedupe
        assert_ne!(hash_f32s(&[0.0]), hash_f32s(&[-0.0]));
    }

    fn sample_manifest() -> ShardManifest {
        ShardManifest {
            total_elems: 12,
            shards: vec![
                ShardEntry {
                    id: 0,
                    kind: SHARD_KIND_RAW,
                    elems: 7,
                    hash: 0xdead_beef,
                    generation: 3,
                    object: ObjectRef { bucket: "shared".into(), key: "a".into(), size: 28 },
                },
                ShardEntry {
                    id: 1,
                    kind: SHARD_KIND_WIRE,
                    elems: 5,
                    hash: 0xfeed_face,
                    generation: 2,
                    object: ObjectRef { bucket: "shared".into(), key: "bb".into(), size: 25 },
                },
            ],
        }
    }

    #[test]
    fn manifest_wire_roundtrip() {
        let m = sample_manifest();
        let wire = m.to_wire();
        assert!(ShardManifest::is_wire(&wire));
        assert_eq!(ShardManifest::from_wire(&wire).unwrap(), m);
    }

    #[test]
    fn manifest_rejects_garbage_and_versions() {
        assert!(!ShardManifest::is_wire(b"WPv1"));
        let err = ShardManifest::from_wire(b"nope").unwrap_err().to_string();
        assert!(err.contains("not an SPv1"), "{err}");
        let err = ShardManifest::from_wire(b"SPv2\x00\x00").unwrap_err().to_string();
        assert!(err.contains("unsupported shard manifest version"), "{err}");
        assert!(ShardManifest::from_wire(b"").is_err());
    }

    #[test]
    fn manifest_rejects_truncation_and_trailing_bytes() {
        let wire = sample_manifest().to_wire();
        // every strict prefix is a truncation error, never a panic
        for cut in 0..wire.len() {
            assert!(
                ShardManifest::from_wire(&wire[..cut]).is_err(),
                "prefix of {cut} bytes parsed"
            );
        }
        let mut long = wire.clone();
        long.push(0xAB);
        let err = ShardManifest::from_wire(&long).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn manifest_rejects_id_and_count_mismatches() {
        let mut m = sample_manifest();
        m.shards[1].id = 7;
        let err = ShardManifest::from_wire(&m.to_wire()).unwrap_err().to_string();
        assert!(err.contains("id 7"), "{err}");
        let mut m = sample_manifest();
        m.total_elems = 13;
        let err = ShardManifest::from_wire(&m.to_wire()).unwrap_err().to_string();
        assert!(err.contains("header claims 13"), "{err}");
    }

    #[test]
    fn verify_shard_checks_len_and_hash() {
        let decoded = vec![1.0f32, 2.0, 3.0];
        let entry = ShardEntry {
            id: 4,
            kind: SHARD_KIND_RAW,
            elems: 3,
            hash: hash_f32s(&decoded),
            generation: 1,
            object: ObjectRef { bucket: "b".into(), key: "k".into(), size: 12 },
        };
        verify_shard(&entry, &decoded).unwrap();
        let err = verify_shard(&entry, &decoded[..2]).unwrap_err().to_string();
        assert!(err.contains("shard 4"), "{err}");
        let err = verify_shard(&entry, &[1.0, 2.0, 4.0]).unwrap_err().to_string();
        assert!(err.contains("hash mismatch"), "{err}");
    }

    #[test]
    fn split_reassemble_roundtrip() {
        // the handler's reassembly path: decode each shard in id order and
        // concatenate — must reproduce the input exactly for any layout
        let params: Vec<f32> = (0..23).map(|i| i as f32 * 1.25 - 7.0).collect();
        for shards in [1usize, 2, 5, 23] {
            let store = ObjectStore::new();
            let p = plane(shards, params.len());
            let st = ShardState::new(p.shard_count());
            let up = upload_sharded(
                &p,
                &st,
                &store,
                PARAMS_BUCKET,
                &params,
                1,
                SHARD_KIND_RAW,
                raw_put(&store, 1),
            )
            .unwrap();
            let m = ShardManifest::from_wire(&store.get_ref(&up.manifest).unwrap()).unwrap();
            assert_eq!(m.total_elems, params.len());
            let mut back = Vec::with_capacity(m.total_elems);
            for e in &m.shards {
                let decoded =
                    crate::util::bytes::bytes_to_f32s(&store.get_ref(&e.object).unwrap());
                verify_shard(e, &decoded).unwrap();
                back.extend_from_slice(&decoded);
            }
            assert_eq!(back, params, "{shards} shards");
        }
    }

    #[test]
    fn steady_state_epoch_puts_exactly_k_changed_shards_plus_manifest() {
        // the ISSUE's exact-counter acceptance: a generation touching k
        // of L shards puts exactly k shard objects + 1 manifest
        let store = ObjectStore::new();
        let total = 40;
        let p = plane(4, total); // L = 4 shards of 10
        let st = ShardState::new(4);
        let mut params: Vec<f32> = (0..total).map(|i| i as f32).collect();
        let up1 = upload_sharded(
            &p, &st, &store, PARAMS_BUCKET, &params, 1, SHARD_KIND_RAW, raw_put(&store, 1),
        )
        .unwrap();
        let puts_after_first = store.stats().0;
        assert_eq!(puts_after_first, 5, "first upload: L shards + manifest");
        assert_eq!((p.changed(), p.reused()), (4, 0));

        // generation 2 touches k = 2 of the 4 shards (shards 1 and 3)
        params[12] += 1.0;
        params[33] -= 1.0;
        let up2 = upload_sharded(
            &p, &st, &store, PARAMS_BUCKET, &params, 2, SHARD_KIND_RAW, raw_put(&store, 2),
        )
        .unwrap();
        assert_eq!(store.stats().0 - puts_after_first, 3, "k=2 shards + 1 manifest");
        assert_eq!((p.total(), p.changed(), p.reused()), (8, 6, 2));
        assert_eq!(p.bytes_saved(), 2 * 10 * 4, "two 10-elem raw shards not re-shipped");
        assert_eq!(up2.reused, vec![true, false, true, false]);

        // reused entries carry the prior generation's objects
        let m2 = ShardManifest::from_wire(&store.get_ref(&up2.manifest).unwrap()).unwrap();
        assert_eq!(m2.shards[0].generation, 1);
        assert_eq!(m2.shards[0].object, up1.shards[0]);
        assert_eq!(m2.shards[1].generation, 2);
        assert_ne!(m2.shards[1].object, up1.shards[1]);
        assert_eq!(store.generation_of(&m2.shards[0].object), Some(1));

        // lifecycle: generation 1's holder releases its refs — the
        // reused objects survive on generation 2's retained references
        for r in &up1.shards {
            store.release(r);
        }
        store.release(&up1.manifest);
        assert!(store.get_ref(&m2.shards[0].object).is_ok(), "reused shard swept early");
        // changed shard 1's generation-1 object is gone (last ref released)
        assert!(store.get_ref(&up1.shards[1]).is_err());
        for r in &up2.shards {
            store.release(r);
        }
        store.release(&up2.manifest);
        assert_eq!(store.total_objects(), 0, "all refs released, store empty");
    }

    #[test]
    fn identical_peers_dedupe_shards_and_manifest() {
        // two synchronous peers (separate states) upload identical
        // bytes: the cluster stores one object per shard + one manifest
        let store = ObjectStore::new();
        let params: Vec<f32> = (0..20).map(|i| i as f32 * 0.5).collect();
        let p = plane(2, params.len());
        let (st_a, st_b) = (ShardState::new(2), ShardState::new(2));
        let up_a = upload_sharded(
            &p, &st_a, &store, PARAMS_BUCKET, &params, 1, SHARD_KIND_RAW, raw_put(&store, 1),
        )
        .unwrap();
        let up_b = upload_sharded(
            &p, &st_b, &store, PARAMS_BUCKET, &params, 1, SHARD_KIND_RAW, raw_put(&store, 1),
        )
        .unwrap();
        assert_eq!(up_a.manifest, up_b.manifest);
        assert_eq!(up_a.shards, up_b.shards);
        assert_eq!(store.stats().0, 3, "2 shards + 1 manifest, once");
        assert_eq!(store.dedup_hits(), 3, "peer B dedup-hit all three");
        // each holder releases independently
        for r in up_a.shards.iter().chain([&up_a.manifest]) {
            store.release(r);
        }
        assert_eq!(store.total_objects(), 3, "peer B's refs keep everything");
        for r in up_b.shards.iter().chain([&up_b.manifest]) {
            store.release(r);
        }
        assert_eq!(store.total_objects(), 0);
    }

    #[test]
    fn vanished_previous_object_falls_back_to_a_fresh_put() {
        // retain() fails when the old object is gone (swept / released
        // elsewhere): the shard re-encodes instead of publishing a
        // dangling manifest entry
        let store = ObjectStore::new();
        let params: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let p = plane(2, params.len());
        let st = ShardState::new(2);
        let up1 = upload_sharded(
            &p, &st, &store, PARAMS_BUCKET, &params, 1, SHARD_KIND_RAW, raw_put(&store, 1),
        )
        .unwrap();
        // simulate a premature sweep of generation 1
        for r in &up1.shards {
            store.release(r);
        }
        store.release(&up1.manifest);
        assert_eq!(store.total_objects(), 0);
        let up2 = upload_sharded(
            &p, &st, &store, PARAMS_BUCKET, &params, 2, SHARD_KIND_RAW, raw_put(&store, 2),
        )
        .unwrap();
        assert_eq!(up2.reused, vec![false, false], "dead objects cannot be reused");
        assert_eq!(p.changed(), 4);
        let m2 = ShardManifest::from_wire(&store.get_ref(&up2.manifest).unwrap()).unwrap();
        for e in &m2.shards {
            assert_eq!(e.generation, 2);
            assert!(store.get_ref(&e.object).is_ok());
        }
    }

    #[test]
    fn failed_encode_releases_everything_acquired() {
        let store = ObjectStore::new();
        let params: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let p = plane(3, params.len());
        let st = ShardState::new(3);
        let mut calls = 0;
        let err = upload_sharded(
            &p,
            &st,
            &store,
            PARAMS_BUCKET,
            &params,
            1,
            SHARD_KIND_RAW,
            |i, slice| {
                calls += 1;
                if i == 2 {
                    return Err(Error::Store("injected encode failure".into()));
                }
                let r = store.put_dedup(PARAMS_BUCKET, Bytes::from(f32s_to_bytes(slice)), 1)?;
                Ok((r, slice.to_vec()))
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("injected"), "{err}");
        assert_eq!(calls, 3);
        assert_eq!(store.total_objects(), 0, "failed upload must leak nothing");
    }

    #[test]
    fn upload_rejects_layout_mismatch_and_off_plane() {
        let store = ObjectStore::new();
        let p = plane(2, 8);
        let st = ShardState::new(2);
        let short = vec![0.0f32; 5];
        assert!(upload_sharded(
            &p, &st, &store, PARAMS_BUCKET, &short, 1, SHARD_KIND_RAW, raw_put(&store, 1),
        )
        .is_err());
        let off = ShardPlane::off();
        assert!(!off.on());
        let st0 = ShardState::new(0);
        assert!(upload_sharded(
            &off, &st0, &store, PARAMS_BUCKET, &short, 1, SHARD_KIND_RAW, raw_put(&store, 1),
        )
        .is_err());
    }
}
