//! L3 hot-path bench: broker publish/consume throughput at gradient
//! payload sizes (perf target: >=10k msg/s — see DESIGN.md §Perf).

use p2pless::broker::{Broker, Message, QueueMode};
use p2pless::harness::bench::{header, Bench};
use p2pless::util::Bytes;

fn main() {
    header(
        "broker_throughput",
        "publish + peek on LatestOnly queues (the gradient exchange hot path)",
    );
    let mut b = Bench::new("broker").with_samples(5, 30);
    for &size in &[64usize, 4 * 1024, 256 * 1024, 4 * 1024 * 1024] {
        let broker = Broker::default();
        let q = broker.declare("g", QueueMode::LatestOnly).unwrap();
        let payload = Bytes::from(vec![0u8; size]);
        let iters = 1000;
        b.bench_throughput(
            &format!("publish_peek_{}B", size),
            iters as f64,
            "msg",
            || {
                for i in 0..iters {
                    q.publish(Message::new(0, i, payload.clone())).unwrap();
                    std::hint::black_box(q.peek_latest());
                }
            },
        );
    }

    // barrier round: P publishes + P waits
    let mut b = Bench::new("barrier").with_samples(5, 20);
    for &peers in &[2usize, 4, 8, 16] {
        b.bench(&format!("barrier_{peers}_peers"), || {
            let broker = std::sync::Arc::new(Broker::default());
            let bar = std::sync::Arc::new(
                p2pless::coordinator::EpochBarrier::new(&broker, peers).unwrap(),
            );
            let handles: Vec<_> = (0..peers)
                .map(|r| {
                    let bar = bar.clone();
                    std::thread::spawn(move || bar.arrive_and_wait(r, 1).unwrap())
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }
}
