//! L3 hot-path bench: broker publish/consume throughput at gradient
//! payload sizes (perf target: >=10k msg/s — see DESIGN.md §Perf), plus
//! the branch scheduler's admission path (fair vs greedy dispatch).

use p2pless::broker::{Broker, Message, QueueMode};
use p2pless::faas::{BranchScheduler, Executor};
use p2pless::harness::bench::{header, Bench};
use p2pless::util::Bytes;
use std::sync::Arc;

fn main() {
    header(
        "broker_throughput",
        "publish + peek on LatestOnly queues (the gradient exchange hot path)",
    );
    let mut b = Bench::new("broker").with_samples(5, 30);
    for &size in &[64usize, 4 * 1024, 256 * 1024, 4 * 1024 * 1024] {
        let broker = Broker::default();
        let q = broker.declare("g", QueueMode::LatestOnly).unwrap();
        let payload = Bytes::from(vec![0u8; size]);
        let iters = 1000;
        b.bench_throughput(
            &format!("publish_peek_{}B", size),
            iters as f64,
            "msg",
            || {
                for i in 0..iters {
                    q.publish(Message::new(0, i, payload.clone())).unwrap();
                    std::hint::black_box(q.peek_latest());
                }
            },
        );
    }

    // real concurrency: T peer threads publishing to their own queues
    // while peeking every other queue (the cluster exchange shape)
    let mut b = Bench::new("concurrent").with_samples(3, 15);
    for &threads in &[2usize, 4, 8] {
        let iters = 200u64;
        b.bench_throughput(
            &format!("exchange_{threads}_peers"),
            (threads as u64 * iters) as f64,
            "msg",
            || {
                let broker = std::sync::Arc::new(Broker::default());
                for r in 0..threads {
                    broker
                        .declare(&Broker::gradient_queue(r), QueueMode::LatestOnly)
                        .unwrap();
                }
                let handles: Vec<_> = (0..threads)
                    .map(|r| {
                        let broker = broker.clone();
                        std::thread::spawn(move || {
                            let payload = Bytes::from(vec![0u8; 4 * 1024]);
                            for e in 0..iters {
                                broker
                                    .publish(
                                        &Broker::gradient_queue(r),
                                        Message::new(r, e, payload.clone()),
                                    )
                                    .unwrap();
                                for other in 0..threads {
                                    if other != r {
                                        let q =
                                            broker.get(&Broker::gradient_queue(other)).unwrap();
                                        std::hint::black_box(q.peek_latest());
                                    }
                                }
                            }
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
            },
        );
    }

    // scheduler admission: 4 peer lanes x 256 no-op branches through a
    // 4-thread pool — the cost of the round-robin gate itself vs the
    // greedy baseline (both must stay far above fan-out rates). The
    // pool/scheduler live outside the timed closure so thread spawn and
    // join never pollute the dispatch numbers.
    let mut b = Bench::new("sched").with_samples(2, 8);
    for &fair in &[true, false] {
        let iters = 256usize;
        let peers = 4usize;
        let scheduler = BranchScheduler::new(Arc::new(Executor::new(4)), fair);
        b.bench_throughput(
            &format!("dispatch_4x256_fair_{fair}"),
            (peers * iters) as f64,
            "branch",
            move || {
                let handles: Vec<_> = (0..iters)
                    .flat_map(|_| {
                        (0..peers).map(|rank| scheduler.submit(rank, || ()))
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
            },
        );
    }

    // barrier round: P publishes + P waits
    let mut b = Bench::new("barrier").with_samples(5, 20);
    for &peers in &[2usize, 4, 8, 16] {
        b.bench(&format!("barrier_{peers}_peers"), || {
            let broker = std::sync::Arc::new(Broker::default());
            let bar = std::sync::Arc::new(
                p2pless::coordinator::EpochBarrier::new(&broker, peers).unwrap(),
            );
            let handles: Vec<_> = (0..peers)
                .map(|r| {
                    let bar = bar.clone();
                    std::thread::spawn(move || bar.arrive_and_wait(r, 1).unwrap())
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }
}
