//! Compression codec bench (fig 5's cost side): QSGD encode/decode
//! throughput at the paper's gradient sizes — SqueezeNet (1.2M),
//! MobileNet (2.5M) — plus raw and top-k baselines.

use p2pless::compress::{Codec, QsgdCodec, RawCodec, TopkCodec};
use p2pless::harness::bench::{header, Bench};
use p2pless::util::Rng;

fn grad(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect()
}

fn main() {
    header(
        "qsgd_codec",
        "gradient codecs at paper model sizes (elements/s; raw = memcpy floor)",
    );
    for &(name, n) in &[("squeezenet_1.2M", 1_200_000usize), ("mobilenet_2.5M", 2_500_000)] {
        let v = grad(n, 1);
        let mut b = Bench::new(name).with_samples(2, 8);

        let raw = RawCodec;
        let wire = raw.encode(&v).unwrap();
        b.bench_throughput("raw_encode", n as f64, "elem", || raw.encode(&v).unwrap());
        b.bench_throughput("raw_decode", n as f64, "elem", || raw.decode(&wire).unwrap());

        let q = QsgdCodec::new(16, 7);
        let wire = q.encode(&v).unwrap();
        println!(
            "  qsgd wire: {} bytes ({:.2}x smaller)",
            wire.len(),
            (n * 4) as f64 / wire.len() as f64
        );
        b.bench_throughput("qsgd16_encode", n as f64, "elem", || q.encode(&v).unwrap());
        b.bench_throughput("qsgd16_decode", n as f64, "elem", || q.decode(&wire).unwrap());

        let t = TopkCodec::new(0.01);
        let wire = t.encode(&v).unwrap();
        b.bench_throughput("topk1%_encode", n as f64, "elem", || t.encode(&v).unwrap());
        b.bench_throughput("topk1%_decode", n as f64, "elem", || t.decode(&wire).unwrap());
    }
}
