//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. L1 kernel ablation: grad artifact with the Pallas tiled matmul
//!    vs the pure-jnp (`_nopallas`) lowering — same math, different
//!    kernel structure.
//! 2. Barrier ablation: sync vs async epoch wall on a real cluster.
//! 3. Wire ablation: gradient publish with raw vs QSGD vs top-k codecs.
//!
//! Needs `make artifacts`.

use std::sync::Arc;

use p2pless::broker::{Broker, QueueMode};
use p2pless::compress::{codec_for, Codec};
use p2pless::config::{Compression, SyncMode, TrainConfig};
use p2pless::coordinator::{Cluster, GradientWire};
use p2pless::data::{DatasetKind, SyntheticDataset};
use p2pless::harness::bench::{header, Bench};
use p2pless::runtime::{Engine, ModelRuntime};
use p2pless::store::ObjectStore;
use p2pless::util::Rng;

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else if std::path::Path::new("../artifacts/manifest.json").exists() {
        Some("../artifacts")
    } else {
        None
    }
}

fn main() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP ablations: run `make artifacts` first");
        return;
    };
    let engine = Arc::new(Engine::new().unwrap());

    // ---- 1. pallas vs nopallas grad artifact -------------------------
    header("ablation_pallas", "L1 tiled-matmul kernel vs pure-jnp lowering (same math)");
    let data = SyntheticDataset::new(DatasetKind::Mnist, 1).generate(64);
    for key in ["mini_squeezenet_mnist", "mini_vgg_mnist"] {
        let rt = ModelRuntime::load(engine.clone(), dir, key).unwrap();
        let params = rt.init_params().unwrap();
        let mut b = Bench::new(key).with_samples(1, 2);
        b.bench("grad_b64_pallas", || {
            rt.grad(64, &params, &data.x, &data.y, true).unwrap()
        });
        b.bench("grad_b64_nopallas", || {
            rt.grad(64, &params, &data.x, &data.y, false).unwrap()
        });
    }

    // ---- 2. sync vs async epoch wall ---------------------------------
    header("ablation_barrier", "sync barrier vs async exchange, 2 peers x 1 epoch");
    let base = TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs: 1,
        train_samples: 2 * 16 * 2,
        val_samples: 64,
        artifacts_dir: dir.into(),
        ..Default::default()
    };
    let mut b = Bench::new("cluster").with_samples(1, 2);
    for (name, sync) in [
        ("sync_epoch", SyncMode::Synchronous),
        ("async_epoch", SyncMode::Asynchronous),
    ] {
        let cfg = TrainConfig { sync, ..base.clone() };
        let engine = engine.clone();
        b.bench(name, move || {
            Cluster::with_engine(cfg.clone(), engine.clone())
                .unwrap()
                .run()
                .unwrap()
        });
    }

    // ---- 3. wire codecs on the publish path ---------------------------
    header(
        "ablation_wire",
        "gradient publish+decode via GradientWire per codec (2.5M params)",
    );
    let mut rng = Rng::seed_from_u64(5);
    let grad: Vec<f32> = (0..2_500_000).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();
    let mut b = Bench::new("wire").with_samples(1, 2);
    for spec in ["none", "qsgd:16", "topk:0.01"] {
        let compression = Compression::parse(spec).unwrap();
        let store = Arc::new(ObjectStore::new());
        let codec: Arc<dyn Codec> = Arc::from(codec_for(compression, 1));
        let wire = GradientWire::new(codec, store, usize::MAX);
        let broker = Broker::default();
        broker
            .declare("peer.0.gradients", QueueMode::LatestOnly)
            .unwrap();
        b.bench(&format!("publish_decode_{spec}"), || {
            wire.publish(&broker, 0, 1, &grad).unwrap();
            let m = broker
                .get("peer.0.gradients")
                .unwrap()
                .peek_latest()
                .unwrap();
            std::hint::black_box(wire.decode(&m.payload).unwrap());
        });
    }
}
