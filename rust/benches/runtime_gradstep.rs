//! Runtime bench: real PJRT gradient steps per model — the per-batch
//! hot spot everything else orbits (Table I compute stage).
//!
//! Needs `make artifacts`.

use std::sync::Arc;

use p2pless::data::{DatasetKind, SyntheticDataset};
use p2pless::harness::bench::{header, Bench};
use p2pless::runtime::{Engine, ModelRuntime};

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else if std::path::Path::new("../artifacts/manifest.json").exists() {
        Some("../artifacts")
    } else {
        None
    }
}

fn main() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP runtime_gradstep: run `make artifacts` first");
        return;
    };
    header(
        "runtime_gradstep",
        "PJRT grad/update/eval wall times (mini models, interpret-mode pallas inside)",
    );
    let engine = Arc::new(Engine::new().unwrap());
    let data16 = SyntheticDataset::new(DatasetKind::Mnist, 1).generate(16);
    let data64 = SyntheticDataset::new(DatasetKind::Mnist, 2).generate(64);

    for key in ["mini_squeezenet_mnist", "mini_mobilenet_mnist", "mini_vgg_mnist"] {
        let rt = ModelRuntime::load(engine.clone(), dir, key).unwrap();
        let params = rt.init_params().unwrap();
        let mut b = Bench::new(key).with_samples(1, 3);
        b.bench_throughput("grad_b16", 16.0, "sample", || {
            rt.grad(16, &params, &data16.x, &data16.y, true).unwrap()
        });
        b.bench_throughput("grad_b64", 64.0, "sample", || {
            rt.grad(64, &params, &data64.x, &data64.y, true).unwrap()
        });
        let g = vec![0.01f32; params.len()];
        b.bench("sgd_update", || rt.update(&params, &g, 0.05).unwrap());
        b.bench("eval_b64", || {
            rt.eval(64, &params, &data64.x, &data64.y).unwrap()
        });
    }
}
