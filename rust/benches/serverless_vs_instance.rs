//! Fig-3 bench: the serverless-vs-instance comparison at both scales —
//! modeled cloud cells (state-machine execution cost), the real
//! worker-pool fan-out at several thread counts, the pipelined-vs-staged
//! epoch dispatch, and a real two-peer PJRT run per backend and mode.

use p2pless::broker::{Broker, Message, QueueMode};
use p2pless::compress::WirePlane;
use p2pless::config::{Backend, FailurePolicy, OffloadMode, TrainConfig};
use p2pless::coordinator::{
    Cluster, EpochBarrier, Membership, PartitionHandle, ServerlessOffload,
};
use p2pless::data::{Batcher, DatasetKind, SyntheticDataset};
use p2pless::error::Error;
use p2pless::faas::{
    BranchScheduler, Executor, FaasPlatform, FunctionSpec, Handler, PipelinedMap,
    RetryPolicy, StateMachine,
};
use p2pless::faas::Semaphore;
use p2pless::harness::bench::{header, Bench};
use p2pless::harness::cloud_exps::fig3_cell;
use p2pless::harness::faults::{FaultPlanSpec, FaultScope};
use p2pless::perfmodel::PaperModel;
use p2pless::runtime::{literal_f32, Engine, ExecBatcher, FuseKey, ModelRuntime};
use p2pless::store::{shard::ShardPlane, DecodedCache, ObjectRef, ObjectStore};
use p2pless::util::{Bytes, Json};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn main() {
    header(
        "serverless_vs_instance",
        "modeled fig-3 cells + real worker-pool fan-out + real two-peer runs per backend",
    );
    // CI sets BENCH_FUSED_ONLY to skip the sleep-driven synthetic
    // sections and go straight to the fused-exec comparison + JSON;
    // BENCH_STACKED_ONLY runs only the stacked three-way below;
    // BENCH_FAULTS_ONLY runs only the fault-tolerance sweep;
    // BENCH_CHAOS_ONLY runs only the churn × store-fault chaos sweep
    let fused_only = std::env::var_os("BENCH_FUSED_ONLY").is_some();
    let stacked_only = std::env::var_os("BENCH_STACKED_ONLY").is_some();
    if std::env::var_os("BENCH_FAULTS_ONLY").is_some() {
        bench_faults();
        return;
    }
    if std::env::var_os("BENCH_CHAOS_ONLY").is_some() {
        bench_chaos();
        return;
    }

    // true stacked execution, synthetic three-way: the real ExecBatcher
    // under a serialized slot with a fixed per-XLA-dispatch overhead —
    // the shape the stacked artifacts remove. Unbatched pays the
    // overhead once per branch, fused (PR-5 back-to-back) still pays it
    // once per member turn, stacked pays it ONCE per group. All counts
    // in the committed JSON are content-independent integers (walls go
    // to stdout only), so the file is byte-stable across runs.
    {
        const THREADS: usize = 8;
        const ROUNDS: usize = 16;
        const DISPATCH_OVERHEAD: Duration = Duration::from_micros(300);
        let run = |exec_batch: usize, stack: bool| {
            let batcher =
                Arc::new(ExecBatcher::new(exec_batch, Duration::from_millis(200)));
            let sem = Arc::new(Semaphore::new(1));
            let barrier = Arc::new(std::sync::Barrier::new(THREADS));
            let t0 = Instant::now();
            let handles: Vec<_> = (0..THREADS)
                .map(|t| {
                    let batcher = batcher.clone();
                    let sem = sem.clone();
                    let barrier = barrier.clone();
                    std::thread::spawn(move || {
                        for r in 0..ROUNDS {
                            // every round is a fresh full wave: exactly
                            // one group of THREADS forms per round, so
                            // the dispatch counts are deterministic
                            barrier.wait();
                            let data: Vec<f32> =
                                (0..64).map(|k| (t * 1000 + r * 10 + k) as f32).collect();
                            let inputs = vec![literal_f32(&data, &[64]).unwrap()];
                            let key =
                                FuseKey { exe: 2, batch: 64, params: 0, version: 1 };
                            batcher
                                .run_stacked(
                                    key,
                                    inputs,
                                    &sem,
                                    |ins| {
                                        std::thread::sleep(DISPATCH_OVERHEAD);
                                        let v = ins[0].to_vec::<f32>()?;
                                        let s: f32 = v.iter().sum();
                                        Ok(vec![literal_f32(&[s], &[1])?])
                                    },
                                    move |views| {
                                        if !stack || views.len() < 2 {
                                            return Ok(None);
                                        }
                                        let t0 = Instant::now();
                                        std::thread::sleep(DISPATCH_OVERHEAD);
                                        let mut outs = Vec::with_capacity(views.len());
                                        for v in views {
                                            let x = v[0].to_vec::<f32>()?;
                                            let s: f32 = x.iter().sum();
                                            outs.push(vec![literal_f32(&[s], &[1])?]);
                                        }
                                        Ok(Some((outs, t0.elapsed(), views.len())))
                                    },
                                )
                                .unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            (
                t0.elapsed(),
                batcher.batched_execs(),
                batcher.stacked_execs(),
                batcher.pad_waste(),
            )
        };
        let _ = run(THREADS, true); // warm-up
        let best = |exec_batch: usize, stack: bool| {
            (0..3).map(|_| run(exec_batch, stack)).min_by_key(|r| r.0).unwrap()
        };
        let (un_wall, un_execs, _, _) = best(1, false);
        let (fu_wall, fu_execs, fu_stacked, _) = best(THREADS, false);
        let (st_wall, st_execs, st_stacked, st_pad) = best(THREADS, true);
        println!(
            "stacked_exec(synthetic, slot=1, {} branches): unbatched {un_wall:?} \
             ({un_execs} dispatches) vs fused {fu_wall:?} ({fu_execs} dispatches, \
             back-to-back) vs stacked {st_wall:?} ({st_stacked} stacked XLA \
             executions, pad {st_pad})",
            THREADS * ROUNDS,
        );
        // the counts are the contract — pin them hard so a grouping
        // regression cannot hide behind a byte-stable JSON
        assert_eq!(un_execs, (THREADS * ROUNDS) as u64);
        assert_eq!(fu_execs, ROUNDS as u64, "full waves must fuse per round");
        assert_eq!(fu_stacked, 0, "the declined strategy must not stack");
        assert_eq!(st_execs, ROUNDS as u64);
        assert_eq!(
            st_stacked, ROUNDS as u64,
            "every full fused group must run as ONE stacked execution"
        );
        assert_eq!(st_pad, 0, "exact-fit groups must not pad");
        assert!(
            st_wall < fu_wall,
            "stacked ({st_wall:?}) must beat the back-to-back fused path \
             ({fu_wall:?}) at slot=1 — it pays the dispatch overhead once \
             per group instead of once per member"
        );
        let mut j = Json::obj();
        j.set("bench", "stacked_exec")
            .set("threads", THREADS)
            .set("rounds", ROUNDS)
            .set("branches", THREADS * ROUNDS)
            .set("exec_batch", THREADS)
            .set("unbatched_dispatches", un_execs)
            .set("fused_dispatches", fu_execs)
            .set("stacked_dispatches", st_execs)
            .set("stacked_execs", st_stacked)
            .set("pad_waste", st_pad)
            .set("stacked_faster", st_wall < fu_wall);
        if let Err(e) = std::fs::write("BENCH_stacked_exec.json", j.to_string()) {
            eprintln!("could not write BENCH_stacked_exec.json: {e}");
        }
        if stacked_only {
            return;
        }
    }

    if !fused_only {
    // cost of evaluating a modeled cell (orchestration overhead itself)
    let mut b = Bench::new("modeled").with_samples(3, 10);
    for &(peers, batch) in &[(4usize, 64usize), (12, 1024)] {
        b.bench(&format!("fig3_cell_p{peers}_b{batch}"), || {
            fig3_cell(PaperModel::Vgg11, peers, batch).unwrap()
        });
    }

    // the execution fabric itself: 16-branch fan-out of 5 ms handlers,
    // measured wall as the worker pool widens (modeled outputs are
    // identical at every size — only the measured wall should shrink)
    let mut b = Bench::new("fabric").with_samples(2, 8);
    for &threads in &[1usize, 2, 4, 8] {
        let platform = Arc::new(FaasPlatform::new(Duration::ZERO));
        let busy: Handler = Arc::new(|b: &Bytes| {
            std::thread::sleep(Duration::from_millis(5));
            Ok(b.clone())
        });
        platform.register(FunctionSpec::new("grad", 1024, busy)).unwrap();
        let pool = Executor::new(threads);
        b.bench(&format!("fanout_16x5ms_threads{threads}"), move || {
            let items: Vec<Bytes> = (0..16).map(|_| Bytes::from_static(b"b")).collect();
            let sm = StateMachine::parallel_batches("bench", "grad", items, vec![], 64);
            sm.execute_with(&platform, &pool).unwrap()
        });
    }

    // epoch-boundary overlap: E epochs of N branches with a simulated
    // inter-epoch coordination gap (exchange + eval + barrier) between
    // fan-outs. Pipelined dispatch drains the pool during the gap;
    // cross-epoch dispatches epoch e+1 *before* the gap, so the pool
    // keeps executing across the boundary. Modeled outputs are
    // identical; only the measured boundary idle time moves.
    {
        const EPOCHS: usize = 4;
        const BRANCHES: usize = 8;
        const HANDLER_MS: u64 = 30;
        const COORD_MS: u64 = 60;
        let run = |cross_epoch: bool| {
            let platform = Arc::new(FaasPlatform::new(Duration::ZERO));
            let busy: Handler = Arc::new(|b: &Bytes| {
                std::thread::sleep(Duration::from_millis(HANDLER_MS));
                Ok(b.clone())
            });
            platform.register(FunctionSpec::new("grad", 1024, busy)).unwrap();
            let executor = Arc::new(Executor::new(4));
            let scheduler = BranchScheduler::new(executor.clone(), true);
            let dispatch = |epoch: usize| {
                let mut pipe = PipelinedMap::new(
                    scheduler.clone(),
                    platform.clone(),
                    0,
                    "grad",
                    BRANCHES,
                    64,
                    RetryPolicy::default(),
                )
                .unwrap()
                .with_generation(epoch as u64);
                for _ in 0..BRANCHES {
                    pipe.submit(Bytes::from_static(b"b"), None);
                }
                pipe
            };
            let collect = |mut pipe: PipelinedMap| {
                while pipe.next_output().is_some() {}
                pipe.finish().unwrap()
            };
            let t0 = std::time::Instant::now();
            if cross_epoch {
                // the peer shape: dispatch e+1 right after e's update,
                // then pay the coordination gap while e+1 executes
                let mut pending = dispatch(1);
                for epoch in 1..=EPOCHS {
                    std::thread::sleep(Duration::from_millis(COORD_MS));
                    collect(pending);
                    pending = dispatch(epoch + 1);
                }
                collect(pending);
            } else {
                for epoch in 1..=EPOCHS + 1 {
                    let pipe = dispatch(epoch);
                    collect(pipe);
                    if epoch <= EPOCHS {
                        std::thread::sleep(Duration::from_millis(COORD_MS));
                    }
                }
            }
            t0.elapsed()
        };
        let pipelined_wall = run(false);
        let cross_wall = run(true);
        // (peak in-flight generations is not printed here: with a
        // single offloader each epoch is fully collected before the
        // next dispatch, so cluster-level generation overlap — peers
        // skewed across the boundary — is not visible in this harness)
        let waves = (BRANCHES / 4) as u64;
        let ideal = Duration::from_millis((EPOCHS as u64 + 1) * HANDLER_MS * waves);
        println!(
            "epoch_boundary: pipelined {pipelined_wall:?} (idle ≈ {:?}) vs cross-epoch \
             {cross_wall:?} (idle ≈ {:?}) over {} boundaries of {COORD_MS} ms coordination",
            pipelined_wall.saturating_sub(ideal),
            cross_wall.saturating_sub(ideal),
            EPOCHS,
        );
    }

    // staged vs pipelined epoch dispatch: 12 branches, a 8 ms simulated
    // upload per batch on the caller thread, a 50 ms handler, 4-thread
    // pool — the pipelined path hides later handler waves behind the
    // uploads (modeled outputs are identical; only measured time moves)
    let mut b = Bench::new("pipeline").with_samples(1, 5);
    for &pipelined in &[false, true] {
        let name = if pipelined {
            "epoch_12x50ms_pipelined"
        } else {
            "epoch_12x50ms_staged"
        };
        let platform = Arc::new(FaasPlatform::new(Duration::ZERO));
        let busy: Handler = Arc::new(|b: &Bytes| {
            std::thread::sleep(Duration::from_millis(50));
            Ok(b.clone())
        });
        platform.register(FunctionSpec::new("grad", 1024, busy)).unwrap();
        let executor = Arc::new(Executor::new(4));
        let scheduler = BranchScheduler::new(executor.clone(), true);
        b.bench(name, move || {
            if pipelined {
                let mut pipe = PipelinedMap::new(
                    scheduler.clone(),
                    platform.clone(),
                    0,
                    "grad",
                    12,
                    64,
                    RetryPolicy::default(),
                )
                .unwrap();
                for _ in 0..12 {
                    std::thread::sleep(Duration::from_millis(8)); // "upload"
                    pipe.submit(Bytes::from_static(b"b"), None);
                    while pipe.poll_output().is_some() {}
                }
                while pipe.next_output().is_some() {}
                pipe.finish().unwrap()
            } else {
                let mut items = Vec::with_capacity(12);
                for _ in 0..12 {
                    std::thread::sleep(Duration::from_millis(8)); // "upload"
                    items.push(Bytes::from_static(b"b"));
                }
                let sm =
                    StateMachine::parallel_batches("bench", "grad", items, vec![], 64);
                sm.execute_with(&platform, &executor).unwrap()
            }
        });
    }
    }

    // fused micro-batched execution, synthetic: the real ExecBatcher
    // grouping machinery under a serialized execution slot — the shape
    // where per-dispatch overhead (slot round-trips, worker wakeups)
    // dominates. Unbatched = every branch pays its own dispatch;
    // batched = up to 8 branches ride one.
    let fused_synth = {
        const THREADS: usize = 8;
        const PER_THREAD: usize = 32;
        let run = |exec_batch: usize| {
            let batcher =
                Arc::new(ExecBatcher::new(exec_batch, Duration::from_micros(300)));
            let sem = Arc::new(Semaphore::new(1));
            let t0 = Instant::now();
            let handles: Vec<_> = (0..THREADS)
                .map(|t| {
                    let batcher = batcher.clone();
                    let sem = sem.clone();
                    std::thread::spawn(move || {
                        for i in 0..PER_THREAD {
                            let data: Vec<f32> =
                                (0..64).map(|k| (t * 1000 + i + k) as f32).collect();
                            let inputs =
                                vec![literal_f32(&data, &[64]).unwrap()];
                            let key = FuseKey {
                                exe: 1,
                                batch: 64,
                                params: 0,
                                version: 1,
                            };
                            batcher
                                .run(key, inputs, &sem, |ins| {
                                    let v = ins[0].to_vec::<f32>()?;
                                    let s: f32 = v.iter().sum();
                                    Ok(vec![literal_f32(&[s], &[1])?])
                                })
                                .unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            (t0.elapsed(), batcher.batched_execs(), batcher.fused_branches())
        };
        // warm-up, then best-of-3 per mode
        let _ = run(1);
        let best = |exec_batch: usize| {
            (0..3).map(|_| run(exec_batch)).min_by_key(|r| r.0).unwrap()
        };
        let (un_wall, un_execs, _) = best(1);
        let (fu_wall, fu_execs, fu_branches) = best(8);
        println!(
            "fused_exec(synthetic, slot=1): unbatched {un_wall:?} ({un_execs} \
             dispatches) vs batched {fu_wall:?} ({fu_execs} dispatches for \
             {fu_branches} branches)"
        );
        if fu_wall >= un_wall {
            eprintln!(
                "WARN fused_exec(synthetic): batched did not beat unbatched \
                 ({fu_wall:?} vs {un_wall:?}) — perf trajectory regression"
            );
        }
        let mut j = Json::obj();
        j.set("threads", THREADS)
            .set("branches", THREADS * PER_THREAD)
            .set("exec_batch", 8usize)
            .set("unbatched_wall_us", un_wall.as_micros() as u64)
            .set("batched_wall_us", fu_wall.as_micros() as u64)
            .set("unbatched_dispatches", un_execs)
            .set("batched_dispatches", fu_execs)
            .set("batched_faster", fu_wall < un_wall);
        j
    };
    let write_fused_json = |synth: &Json, real: Option<Json>| {
        let mut j = Json::obj();
        j.set("bench", "fused_exec").set("synthetic", synth.clone());
        match real {
            Some(r) => j.set("real", r),
            None => j.set("real_skipped", true),
        };
        if let Err(e) = std::fs::write("BENCH_fused_exec.json", j.to_string()) {
            eprintln!("could not write BENCH_fused_exec.json: {e}");
        }
    };

    // real execution (needs artifacts)
    let dir = if std::path::Path::new("artifacts/manifest.json").exists() {
        "artifacts"
    } else if std::path::Path::new("../artifacts/manifest.json").exists() {
        "../artifacts"
    } else {
        eprintln!("SKIP real backend bench: run `make artifacts`");
        write_fused_json(&fused_synth, None);
        return;
    };
    let engine = Arc::new(Engine::new().unwrap());
    let base = TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs: 1,
        train_samples: 2 * 16 * 2,
        val_samples: 64,
        artifacts_dir: dir.into(),
        ..Default::default()
    };
    let mut b = Bench::new("real").with_samples(1, 2);
    for (name, backend, mode) in [
        ("instance_epoch", Backend::Instance, OffloadMode::Pipelined),
        ("serverless_epoch_staged", Backend::Serverless, OffloadMode::Staged),
        ("serverless_epoch_pipelined", Backend::Serverless, OffloadMode::Pipelined),
        ("serverless_epoch_cross_epoch", Backend::Serverless, OffloadMode::CrossEpoch),
    ] {
        let cfg = TrainConfig { backend, offload_mode: mode, ..base.clone() };
        let engine = engine.clone();
        b.bench(name, move || {
            Cluster::with_engine(cfg.clone(), engine.clone())
                .unwrap()
                .run()
                .unwrap()
        });
    }

    // warm vs cold data plane: a *cold* epoch pays the one-time batch
    // pack + upload before fanning out; a *warm* epoch reuses the
    // epoch-persistent refs and uploads only the params object. Store
    // puts per epoch are reported alongside the timings — the win the
    // zero-redundancy plane buys is both visible numbers shrinking.
    let runtime = Arc::new(
        ModelRuntime::load(engine.clone(), dir, "mini_squeezenet_mnist").unwrap(),
    );
    let data = SyntheticDataset::new(DatasetKind::Mnist, 11).generate(16 * 4);
    let batches = Batcher::new(16, 11).epoch_batches(&data, 0);
    let params = Arc::new(runtime.init_params().unwrap());
    let offloader = |store: &Arc<ObjectStore>| {
        ServerlessOffload::new(
            Arc::new(FaasPlatform::new(Duration::ZERO)),
            store.clone(),
            runtime.clone(),
            BranchScheduler::new(Arc::new(Executor::new(4)), true),
            Arc::new(DecodedCache::new(16)),
            Arc::new(WirePlane::off()),
            Arc::new(ShardPlane::off()),
            0,
            1769,
            64,
            OffloadMode::Pipelined,
            true,
            2,
        )
        .unwrap()
    };

    let mut b = Bench::new("data_plane").with_samples(1, 4);
    {
        let batches = batches.clone();
        let params = params.clone();
        let runtime = runtime.clone();
        b.bench("epoch_cold_reupload_batches", move || {
            // fresh store + offloader: every "epoch" re-packs and
            // re-uploads the batch objects (the pre-PR shape)
            let store = Arc::new(ObjectStore::new());
            let off = ServerlessOffload::new(
                Arc::new(FaasPlatform::new(Duration::ZERO)),
                store.clone(),
                runtime.clone(),
                BranchScheduler::new(Arc::new(Executor::new(4)), true),
                Arc::new(DecodedCache::new(16)),
                Arc::new(WirePlane::off()),
                Arc::new(ShardPlane::off()),
                0,
                1769,
                64,
                OffloadMode::Pipelined,
                true,
                2,
            )
            .unwrap();
            off.upload_batches(&batches).unwrap();
            off.compute_epoch(1, &params).unwrap()
        });
    }
    let warm_store = Arc::new(ObjectStore::new());
    let warm = Arc::new(offloader(&warm_store));
    warm.upload_batches(&batches).unwrap();
    {
        let warm = warm.clone();
        let params = params.clone();
        let mut epoch = 0usize;
        b.bench("epoch_warm_cached_batches", move || {
            epoch += 1;
            warm.compute_epoch(epoch, &params).unwrap()
        });
    }
    // per-epoch store put counts (one extra epoch each, counted exactly)
    let cold_store = Arc::new(ObjectStore::new());
    let cold = offloader(&cold_store);
    let p0 = cold_store.stats().0;
    cold.upload_batches(&batches).unwrap();
    cold.compute_epoch(1, &params).unwrap();
    let cold_puts = cold_store.stats().0 - p0;
    let p0 = warm_store.stats().0;
    warm.compute_epoch(1000, &params).unwrap();
    let warm_puts = warm_store.stats().0 - p0;
    println!(
        "data_plane: store puts per epoch — cold {} (batch upload + params + {} parked \
         grads), warm {} (params + parked grads only)",
        cold_puts,
        batches.len(),
        warm_puts,
    );

    // fused micro-batched execution, real PJRT: an 8-branch single-peer
    // run under a serialized execution slot, batched vs unbatched. The
    // modeled numbers are byte-identical by contract; what moves is the
    // measured fan-out wall (one fused dispatch per epoch instead of 8
    // slot round-trips through 8 worker wakeups).
    let real_fused = {
        let epochs = 3usize;
        let run = |exec_batch: usize| {
            let cfg = TrainConfig {
                peers: 1,
                batch_size: 16,
                epochs,
                train_samples: 8 * 16, // 8 branches per epoch
                val_samples: 64,
                backend: Backend::Serverless,
                exec_threads: 8,
                exec_slots: 1,
                exec_batch,
                exec_batch_wait_us: 100_000,
                artifacts_dir: dir.into(),
                ..Default::default()
            };
            let engine = Arc::new(
                Engine::with_exec_batching(1, exec_batch, Duration::from_micros(100_000))
                    .unwrap(),
            );
            let warmup = Cluster::with_engine(cfg.clone(), engine.clone())
                .unwrap()
                .run()
                .unwrap();
            let mut best = warmup;
            for _ in 0..2 {
                let rep = Cluster::with_engine(cfg.clone(), engine.clone())
                    .unwrap()
                    .run()
                    .unwrap();
                if rep.lambda_measured_wall < best.lambda_measured_wall {
                    best = rep;
                }
            }
            best
        };
        let unbatched = run(1);
        let batched = run(8);
        println!(
            "fused_exec(real, 8 branches x {epochs} epochs, slot=1): measured fan-out \
             wall {:?} unbatched vs {:?} batched ({} fused dispatches, {}% fill)",
            unbatched.lambda_measured_wall,
            batched.lambda_measured_wall,
            batched.counter("engine.batched_execs").unwrap_or(0),
            batched.counter("engine.batch_fill").unwrap_or(0),
        );
        if batched.lambda_measured_wall >= unbatched.lambda_measured_wall {
            eprintln!(
                "WARN fused_exec(real): batched did not beat unbatched ({:?} vs {:?}) \
                 — perf trajectory regression",
                batched.lambda_measured_wall, unbatched.lambda_measured_wall,
            );
        }
        let mut j = Json::obj();
        j.set("branches_per_epoch", 8usize)
            .set("epochs", epochs)
            .set("exec_slots", 1usize)
            .set(
                "unbatched_measured_wall_us",
                unbatched.lambda_measured_wall.as_micros() as u64,
            )
            .set(
                "batched_measured_wall_us",
                batched.lambda_measured_wall.as_micros() as u64,
            )
            .set(
                "batched_execs",
                batched.counter("engine.batched_execs").unwrap_or(0),
            )
            .set(
                "fused_branches",
                batched.counter("engine.fused_branches").unwrap_or(0),
            )
            .set("batch_fill", batched.counter("engine.batch_fill").unwrap_or(0))
            .set(
                "batched_faster",
                batched.lambda_measured_wall < unbatched.lambda_measured_wall,
            );
        j
    };
    write_fused_json(&fused_synth, Some(real_fused));
}

/// The fault-tolerance sweep (`BENCH_FAULTS_ONLY=1`): seeded kill rate
/// × failure policy × cluster size, driven through the real
/// [`Membership`] table and [`EpochBarrier`], plus a k-of-n fold-quorum
/// sweep and a flaky-handler retry run through the real
/// [`PipelinedMap`]. Every value in the committed JSON is a
/// deterministic integer (schedules are seeded, the bookkeeping is
/// exact), so `BENCH_fault_tolerance.json` is byte-stable across runs
/// and machines — walls go to stdout only.
fn bench_faults() {
    const EPOCHS: usize = 6;
    const SEED: u64 = 11;

    // ---- membership sweep: rate × policy × peers ----------------------
    let policies = [FailurePolicy::Abort, FailurePolicy::Drop, FailurePolicy::Takeover];
    let mut cells: Vec<Json> = Vec::new();
    for &peers in &[4usize, 8] {
        for &rate_pct in &[0usize, 25, 50] {
            let plan = if rate_pct == 0 {
                None
            } else {
                let spec = format!("rate:kill=0.{rate_pct},seed={SEED}");
                Some(FaultPlanSpec::parse(&spec).unwrap().resolve(peers, EPOCHS).unwrap())
            };
            // the seeded schedule as (rank, kill epoch), epoch-ordered
            let mut kills: Vec<(usize, u64)> = (0..peers)
                .filter_map(|r| plan.as_ref().and_then(|p| p.kill_epoch(r)).map(|e| (r, e)))
                .collect();
            kills.sort_by_key(|&(r, e)| (e, r));
            for &policy in &policies {
                let mut cell = Json::obj();
                cell.set("peers", peers)
                    .set("rate_pct", rate_pct)
                    .set("policy", policy.name())
                    .set("kills_scheduled", kills.len());
                if policy == FailurePolicy::Abort {
                    // fail-fast: the run dies with the first casualty
                    let completed =
                        kills.first().map(|&(_, e)| e as usize - 1).unwrap_or(EPOCHS);
                    cell.set("completed_epochs", completed)
                        .set("deaths", 0usize)
                        .set("takeover_epochs", 0usize)
                        .set("dropped_grads", 0usize)
                        .set("barrier_proxies", 0usize)
                        .set("final_leader", 0usize);
                    cells.push(cell);
                    continue;
                }
                // replay the schedule against the real membership plane:
                // kills fire at epoch start, every survivor walks the
                // dead slots exactly like the peer consume loop, and the
                // cumulative barrier must fill via proxies every epoch
                let broker = Arc::new(Broker::default());
                let m = Membership::new(
                    broker.clone(),
                    peers,
                    policy,
                    Duration::from_millis(1),
                    Duration::from_secs(3600),
                    true,
                )
                .unwrap();
                let barrier = EpochBarrier::new(&broker, peers).unwrap();
                for epoch in 1..=EPOCHS as u64 {
                    for &(r, at) in &kills {
                        if at == epoch {
                            m.declare_dead(r, "scheduled kill");
                        }
                    }
                    let alive: Vec<usize> = (0..peers).filter(|&r| m.is_alive(r)).collect();
                    for &me in &alive {
                        for dead in 0..peers {
                            if m.is_alive(dead) {
                                continue;
                            }
                            if m.claim_takeover(me, dead, epoch) {
                                m.note_takeover_published(dead, epoch);
                            } else if policy == FailurePolicy::Drop {
                                m.note_dropped_grad();
                            }
                        }
                    }
                    for &me in &alive {
                        barrier.arrive(me, epoch).unwrap();
                        m.note_barrier_arrival(me, epoch);
                    }
                    m.fill_barrier(&barrier, epoch).unwrap();
                    assert!(
                        barrier.wait_timeout(epoch, Duration::from_secs(5)).unwrap(),
                        "barrier {epoch} must fill via proxies"
                    );
                }
                cell.set("completed_epochs", EPOCHS)
                    .set("deaths", m.deaths())
                    .set("takeover_epochs", m.takeover_epochs())
                    .set("dropped_grads", m.dropped_grads())
                    .set("barrier_proxies", m.barrier_proxies())
                    .set("final_leader", m.leader());
                println!(
                    "faults(p{peers} rate {rate_pct}% {}): {} deaths, {} takeover \
                     epochs, {} dropped, {} proxies, leader {}",
                    policy.name(),
                    m.deaths(),
                    m.takeover_epochs(),
                    m.dropped_grads(),
                    m.barrier_proxies(),
                    m.leader(),
                );
                cells.push(cell);
            }
        }
    }

    // ---- k-of-n fold quorum through the real pipelined Map ------------
    const BRANCHES: usize = 12;
    const CONCURRENCY: usize = 4;
    let echo: Handler = Arc::new(|b: &Bytes| Ok(b.clone()));
    let mut quorum_cells: Vec<Json> = Vec::new();
    for &quorum in &[0usize, BRANCHES / 2, BRANCHES - 1] {
        let platform = Arc::new(FaasPlatform::new(Duration::from_millis(1500)));
        platform.register(FunctionSpec::new("grad", 1024, echo.clone())).unwrap();
        let sched = BranchScheduler::new(Arc::new(Executor::new(4)), true);
        let mut pipe = PipelinedMap::new(
            sched,
            platform,
            0,
            "grad",
            BRANCHES,
            CONCURRENCY,
            RetryPolicy::default(),
        )
        .unwrap()
        .with_quorum(quorum);
        for i in 0..BRANCHES {
            pipe.submit(Bytes::from(vec![i as u8]), Some(Duration::from_millis(100)));
        }
        let mut folded = 0usize;
        while pipe.next_output().is_some() {
            folded += 1;
        }
        let r = pipe.finish().unwrap();
        println!(
            "quorum {quorum} of {BRANCHES}: folded {folded}, stragglers {}, \
             modeled wall {:?}",
            r.stragglers, r.wall,
        );
        let mut cell = Json::obj();
        cell.set("quorum", quorum)
            .set("folded", folded)
            .set("stragglers", r.stragglers)
            .set("invocations", r.invocations)
            .set("cold_starts", r.cold_starts);
        quorum_cells.push(cell);
    }

    // ---- configured retry policy against a deterministic flaky fleet --
    // branches at index % 3 == 0 fail their first attempt; with
    // `--lambda-retries 3` every branch lands and the retry counter is
    // exactly the flaky population
    let attempts: Arc<Mutex<std::collections::HashMap<u8, u32>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let seen = attempts.clone();
    let flaky: Handler = Arc::new(move |b: &Bytes| {
        let idx = b[0];
        let mut map = seen.lock().unwrap();
        let n = map.entry(idx).or_insert(0);
        *n += 1;
        if idx % 3 == 0 && *n == 1 {
            return Err(Error::Faas(format!("branch {idx}: injected first-attempt failure")));
        }
        Ok(b.clone())
    });
    const RETRY_BRANCHES: usize = 8;
    let platform = Arc::new(FaasPlatform::new(Duration::from_millis(1500)));
    platform.register(FunctionSpec::new("grad", 1024, flaky)).unwrap();
    let sched = BranchScheduler::new(Arc::new(Executor::new(4)), true);
    let mut pipe = PipelinedMap::new(
        sched,
        platform,
        0,
        "grad",
        RETRY_BRANCHES,
        CONCURRENCY,
        RetryPolicy::configured(3, 0, SEED),
    )
    .unwrap();
    for i in 0..RETRY_BRANCHES {
        pipe.submit(Bytes::from(vec![i as u8]), Some(Duration::from_millis(100)));
    }
    while pipe.next_output().is_some() {}
    let r = pipe.finish().unwrap();
    let flaky_count = (0..RETRY_BRANCHES).filter(|i| i % 3 == 0).count();
    assert_eq!(r.retries, flaky_count, "one extra attempt per flaky branch");
    println!(
        "retries: {} branches ({flaky_count} flaky), {} extra attempts, all landed",
        RETRY_BRANCHES, r.retries,
    );
    let mut retry_cell = Json::obj();
    retry_cell
        .set("branches", RETRY_BRANCHES)
        .set("flaky", flaky_count)
        .set("retries", r.retries)
        .set("invocations", r.invocations)
        .set("max_attempts", 3usize);

    let mut j = Json::obj();
    j.set("bench", "fault_tolerance")
        .set("epochs", EPOCHS)
        .set("seed", SEED)
        .set("cells", cells)
        .set("quorum_cells", quorum_cells)
        .set("retry", retry_cell);
    if let Err(e) = std::fs::write("BENCH_fault_tolerance.json", j.to_string()) {
        eprintln!("could not write BENCH_fault_tolerance.json: {e}");
    }
}

/// The chaos sweep (`BENCH_CHAOS_ONLY=1`): seeded churn rate (kills
/// plus matching mid-run joins) × store-fault rate × failure policy,
/// replayed against the real elastic [`Membership`] table and the
/// growth-aware [`EpochBarrier`] — admissions, partition splits, shed
/// directives, takeover claims and barrier proxies all exercise the
/// production plane — plus an armed store/broker I/O replay per cell
/// under the shared retry policy, where injected transients, corrupted
/// reads and dropped publishes must all be absorbed. Every value in
/// the committed JSON is a deterministic integer (schedules are
/// seeded, the chaos gates fire once per scheduled event, the
/// bookkeeping is exact), so `BENCH_chaos.json` is byte-stable across
/// runs and machines — walls go to stdout only.
fn bench_chaos() {
    const EPOCHS: usize = 6;
    const SEED: u64 = 13;
    const REFS_PER_RANK: usize = 6;
    const RETRY_MAX: u32 = 3;
    let mut cells: Vec<Json> = Vec::new();
    for &peers in &[4usize, 8] {
        for &churn_pct in &[0usize, 25, 50] {
            for &store_pct in &[0usize, 20] {
                // kills and joins ride the same churn rate so every
                // casualty has a matching mid-run scale-up; two fixed
                // explicit broker faults exercise the publish gate in
                // every cell
                let spec = format!(
                    "rate:kill=0.{churn_pct:02},join=0.{churn_pct:02},\
                     store=0.{store_pct:02},seed={SEED};\
                     brokerdrop:peer1@1;brokerdelay:peer0@2:0ms"
                );

                // ---- armed I/O replay: one put + verified get + publish
                // per (rank, epoch) cell under that peer's fault scope —
                // every scheduled store/broker fault fires exactly once
                let parsed = FaultPlanSpec::parse(&spec).unwrap();
                let plan = Arc::new(parsed.resolve(peers, EPOCHS).unwrap());
                let store = ObjectStore::new();
                let chaos_broker = Broker::default();
                let retry = RetryPolicy::configured(RETRY_MAX, 0, SEED);
                store.arm_chaos(plan.clone(), retry);
                chaos_broker.arm_chaos(plan.clone(), retry);
                store.create_bucket("chaos");
                chaos_broker.declare("chaos.sync", QueueMode::Fifo).unwrap();
                for epoch in 1..=EPOCHS as u64 {
                    for rank in 0..peers {
                        let _scope = FaultScope::enter(rank, epoch);
                        let payload = Bytes::from(vec![rank as u8, epoch as u8, 0xC5]);
                        let key = format!("r{rank}-e{epoch}");
                        let r = store.put_gen("chaos", &key, payload.clone(), epoch).unwrap();
                        let back = store.get_ref(&r).unwrap();
                        assert_eq!(back, payload, "verified get must round-trip");
                        chaos_broker
                            .publish("chaos.sync", Message::new(rank, epoch, payload))
                            .unwrap();
                    }
                }
                let io = (
                    store.chaos_retries(),
                    store.corrupt_refetches(),
                    chaos_broker.chaos_retries(),
                    plan.store_faults_fired(),
                    plan.broker_faults_fired(),
                );
                println!(
                    "chaos(p{peers} churn {churn_pct}% store {store_pct}%): \
                     {} store retries, {} corrupt refetches, {} broker retries, \
                     {} store + {} broker faults fired",
                    io.0, io.1, io.2, io.3, io.4,
                );

                // ---- membership replay: boundary admissions land first
                // (the trainer's step order), then scheduled kills, then
                // the survivors' consume walk; the cumulative barrier
                // must fill via proxies every epoch
                for &policy in &[FailurePolicy::Drop, FailurePolicy::Takeover] {
                    let plan = parsed.resolve(peers, EPOCHS).unwrap();
                    let mut kills: Vec<(usize, u64)> = (0..peers)
                        .filter_map(|r| plan.kill_epoch(r).map(|e| (r, e)))
                        .collect();
                    kills.sort_by_key(|&(r, e)| (e, r));
                    let joins = plan.join_events();
                    let broker = Arc::new(Broker::default());
                    let m = Membership::new(
                        broker.clone(),
                        peers,
                        policy,
                        Duration::from_millis(1),
                        Duration::from_secs(3600),
                        true,
                    )
                    .unwrap();
                    m.set_join_schedule(&joins).unwrap();
                    for r in 0..peers {
                        let refs = (0..REFS_PER_RANK)
                            .map(|i| ObjectRef {
                                bucket: "chaos".into(),
                                key: format!("p{r}-b{i}"),
                                size: 1,
                            })
                            .collect();
                        m.register_partition(r, PartitionHandle::Refs(refs));
                    }
                    let growth = m.growth_epochs();
                    let barrier = EpochBarrier::with_growth(&broker, peers, growth).unwrap();
                    let mut sheds_taken = 0usize;
                    for epoch in 1..=EPOCHS as u64 {
                        for (jrank, jepoch) in m.pending_joins_at(epoch) {
                            let adm = m
                                .admit_join(jrank, jepoch)
                                .unwrap()
                                .expect("rate plans schedule growth joins only");
                            m.proxy_catch_up(&barrier, jrank, &adm.catch_up).unwrap();
                        }
                        for &(r, at) in &kills {
                            if at == epoch {
                                m.declare_dead(r, "scheduled kill");
                            }
                        }
                        let width = m.width_at(epoch);
                        let alive: Vec<usize> = (0..width).filter(|&r| m.is_alive(r)).collect();
                        for &me in &alive {
                            if m.take_shed(me, epoch).is_some() {
                                sheds_taken += 1;
                            }
                        }
                        for &me in &alive {
                            for dead in 0..width {
                                if m.is_alive(dead) || m.awaiting_join(dead, epoch) {
                                    continue;
                                }
                                if m.claim_takeover(me, dead, epoch) {
                                    m.note_takeover_published(dead, epoch);
                                } else if policy == FailurePolicy::Drop {
                                    m.note_dropped_grad();
                                }
                            }
                        }
                        for &me in &alive {
                            barrier.arrive(me, epoch).unwrap();
                            m.note_barrier_arrival(me, epoch);
                        }
                        m.fill_barrier(&barrier, epoch).unwrap();
                        assert!(
                            barrier.wait_timeout(epoch, Duration::from_secs(5)).unwrap(),
                            "barrier {epoch} must fill via proxies"
                        );
                    }
                    println!(
                        "chaos(p{peers} churn {churn_pct}% store {store_pct}% {}): \
                         {} deaths, {} joins, width {}, {} takeover epochs, \
                         {} dropped, {} proxies, {} sheds, leader {}",
                        policy.name(),
                        m.deaths(),
                        m.joins(),
                        m.width_at(EPOCHS as u64),
                        m.takeover_epochs(),
                        m.dropped_grads(),
                        m.barrier_proxies(),
                        sheds_taken,
                        m.leader(),
                    );
                    let mut cell = Json::obj();
                    cell.set("peers", peers)
                        .set("churn_pct", churn_pct)
                        .set("store_pct", store_pct)
                        .set("policy", policy.name())
                        .set("kills_scheduled", kills.len())
                        .set("joins_scheduled", joins.len())
                        .set("joins_admitted", m.joins())
                        .set("final_width", m.width_at(EPOCHS as u64))
                        .set("deaths", m.deaths())
                        .set("takeover_epochs", m.takeover_epochs())
                        .set("dropped_grads", m.dropped_grads())
                        .set("barrier_proxies", m.barrier_proxies())
                        .set("sheds_consumed", sheds_taken)
                        .set("final_leader", m.leader())
                        .set("store_retries", io.0)
                        .set("corrupt_refetches", io.1)
                        .set("broker_retries", io.2)
                        .set("store_faults_fired", io.3)
                        .set("broker_faults_fired", io.4);
                    cells.push(cell);
                }
            }
        }
    }

    let mut j = Json::obj();
    j.set("bench", "chaos")
        .set("epochs", EPOCHS)
        .set("seed", SEED)
        .set("retry_max_attempts", RETRY_MAX as usize)
        .set("cells", cells);
    if let Err(e) = std::fs::write("BENCH_chaos.json", j.to_string()) {
        eprintln!("could not write BENCH_chaos.json: {e}");
    }
}
