//! Fig-3 bench: the serverless-vs-instance comparison at both scales —
//! modeled cloud cells (state-machine execution cost), the real
//! worker-pool fan-out at several thread counts, the pipelined-vs-staged
//! epoch dispatch, and a real two-peer PJRT run per backend and mode.

use p2pless::config::{Backend, OffloadMode, TrainConfig};
use p2pless::coordinator::Cluster;
use p2pless::faas::{
    BranchScheduler, Executor, FaasPlatform, FunctionSpec, Handler, PipelinedMap,
    RetryPolicy, StateMachine,
};
use p2pless::harness::bench::{header, Bench};
use p2pless::harness::cloud_exps::fig3_cell;
use p2pless::perfmodel::PaperModel;
use p2pless::runtime::Engine;
use p2pless::util::Bytes;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    header(
        "serverless_vs_instance",
        "modeled fig-3 cells + real worker-pool fan-out + real two-peer runs per backend",
    );

    // cost of evaluating a modeled cell (orchestration overhead itself)
    let mut b = Bench::new("modeled").with_samples(3, 10);
    for &(peers, batch) in &[(4usize, 64usize), (12, 1024)] {
        b.bench(&format!("fig3_cell_p{peers}_b{batch}"), || {
            fig3_cell(PaperModel::Vgg11, peers, batch).unwrap()
        });
    }

    // the execution fabric itself: 16-branch fan-out of 5 ms handlers,
    // measured wall as the worker pool widens (modeled outputs are
    // identical at every size — only the measured wall should shrink)
    let mut b = Bench::new("fabric").with_samples(2, 8);
    for &threads in &[1usize, 2, 4, 8] {
        let platform = Arc::new(FaasPlatform::new(Duration::ZERO));
        let busy: Handler = Arc::new(|b: &Bytes| {
            std::thread::sleep(Duration::from_millis(5));
            Ok(b.clone())
        });
        platform.register(FunctionSpec::new("grad", 1024, busy)).unwrap();
        let pool = Executor::new(threads);
        b.bench(&format!("fanout_16x5ms_threads{threads}"), move || {
            let items: Vec<Bytes> = (0..16).map(|_| Bytes::from_static(b"b")).collect();
            let sm = StateMachine::parallel_batches("bench", "grad", items, vec![], 64);
            sm.execute_with(&platform, &pool).unwrap()
        });
    }

    // staged vs pipelined epoch dispatch: 12 branches, a 8 ms simulated
    // upload per batch on the caller thread, a 50 ms handler, 4-thread
    // pool — the pipelined path hides later handler waves behind the
    // uploads (modeled outputs are identical; only measured time moves)
    let mut b = Bench::new("pipeline").with_samples(1, 5);
    for &pipelined in &[false, true] {
        let name = if pipelined {
            "epoch_12x50ms_pipelined"
        } else {
            "epoch_12x50ms_staged"
        };
        let platform = Arc::new(FaasPlatform::new(Duration::ZERO));
        let busy: Handler = Arc::new(|b: &Bytes| {
            std::thread::sleep(Duration::from_millis(50));
            Ok(b.clone())
        });
        platform.register(FunctionSpec::new("grad", 1024, busy)).unwrap();
        let executor = Arc::new(Executor::new(4));
        let scheduler = BranchScheduler::new(executor.clone(), true);
        b.bench(name, move || {
            if pipelined {
                let mut pipe = PipelinedMap::new(
                    scheduler.clone(),
                    platform.clone(),
                    0,
                    "grad",
                    12,
                    64,
                    RetryPolicy::default(),
                )
                .unwrap();
                for _ in 0..12 {
                    std::thread::sleep(Duration::from_millis(8)); // "upload"
                    pipe.submit(Bytes::from_static(b"b"), None);
                    while pipe.poll_output().is_some() {}
                }
                while pipe.next_output().is_some() {}
                pipe.finish().unwrap()
            } else {
                let mut items = Vec::with_capacity(12);
                for _ in 0..12 {
                    std::thread::sleep(Duration::from_millis(8)); // "upload"
                    items.push(Bytes::from_static(b"b"));
                }
                let sm =
                    StateMachine::parallel_batches("bench", "grad", items, vec![], 64);
                sm.execute_with(&platform, &executor).unwrap()
            }
        });
    }

    // real execution (needs artifacts)
    let dir = if std::path::Path::new("artifacts/manifest.json").exists() {
        "artifacts"
    } else if std::path::Path::new("../artifacts/manifest.json").exists() {
        "../artifacts"
    } else {
        eprintln!("SKIP real backend bench: run `make artifacts`");
        return;
    };
    let engine = Arc::new(Engine::new().unwrap());
    let base = TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs: 1,
        train_samples: 2 * 16 * 2,
        val_samples: 64,
        artifacts_dir: dir.into(),
        ..Default::default()
    };
    let mut b = Bench::new("real").with_samples(1, 2);
    for (name, backend, mode) in [
        ("instance_epoch", Backend::Instance, OffloadMode::Pipelined),
        ("serverless_epoch_staged", Backend::Serverless, OffloadMode::Staged),
        ("serverless_epoch_pipelined", Backend::Serverless, OffloadMode::Pipelined),
    ] {
        let cfg = TrainConfig { backend, offload_mode: mode, ..base.clone() };
        let engine = engine.clone();
        b.bench(name, move || {
            Cluster::with_engine(cfg.clone(), engine.clone())
                .unwrap()
                .run()
                .unwrap()
        });
    }
}
