//! Fig-3 bench: the serverless-vs-instance comparison at both scales —
//! modeled cloud cells (state-machine execution cost), the real
//! worker-pool fan-out at several thread counts, the pipelined-vs-staged
//! epoch dispatch, and a real two-peer PJRT run per backend and mode.

use p2pless::config::{Backend, OffloadMode, TrainConfig};
use p2pless::coordinator::{Cluster, ServerlessOffload};
use p2pless::data::{Batcher, DatasetKind, SyntheticDataset};
use p2pless::faas::{
    BranchScheduler, Executor, FaasPlatform, FunctionSpec, Handler, PipelinedMap,
    RetryPolicy, StateMachine,
};
use p2pless::harness::bench::{header, Bench};
use p2pless::harness::cloud_exps::fig3_cell;
use p2pless::perfmodel::PaperModel;
use p2pless::runtime::{Engine, ModelRuntime};
use p2pless::store::{DecodedCache, ObjectStore};
use p2pless::util::Bytes;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    header(
        "serverless_vs_instance",
        "modeled fig-3 cells + real worker-pool fan-out + real two-peer runs per backend",
    );

    // cost of evaluating a modeled cell (orchestration overhead itself)
    let mut b = Bench::new("modeled").with_samples(3, 10);
    for &(peers, batch) in &[(4usize, 64usize), (12, 1024)] {
        b.bench(&format!("fig3_cell_p{peers}_b{batch}"), || {
            fig3_cell(PaperModel::Vgg11, peers, batch).unwrap()
        });
    }

    // the execution fabric itself: 16-branch fan-out of 5 ms handlers,
    // measured wall as the worker pool widens (modeled outputs are
    // identical at every size — only the measured wall should shrink)
    let mut b = Bench::new("fabric").with_samples(2, 8);
    for &threads in &[1usize, 2, 4, 8] {
        let platform = Arc::new(FaasPlatform::new(Duration::ZERO));
        let busy: Handler = Arc::new(|b: &Bytes| {
            std::thread::sleep(Duration::from_millis(5));
            Ok(b.clone())
        });
        platform.register(FunctionSpec::new("grad", 1024, busy)).unwrap();
        let pool = Executor::new(threads);
        b.bench(&format!("fanout_16x5ms_threads{threads}"), move || {
            let items: Vec<Bytes> = (0..16).map(|_| Bytes::from_static(b"b")).collect();
            let sm = StateMachine::parallel_batches("bench", "grad", items, vec![], 64);
            sm.execute_with(&platform, &pool).unwrap()
        });
    }

    // epoch-boundary overlap: E epochs of N branches with a simulated
    // inter-epoch coordination gap (exchange + eval + barrier) between
    // fan-outs. Pipelined dispatch drains the pool during the gap;
    // cross-epoch dispatches epoch e+1 *before* the gap, so the pool
    // keeps executing across the boundary. Modeled outputs are
    // identical; only the measured boundary idle time moves.
    {
        const EPOCHS: usize = 4;
        const BRANCHES: usize = 8;
        const HANDLER_MS: u64 = 30;
        const COORD_MS: u64 = 60;
        let run = |cross_epoch: bool| {
            let platform = Arc::new(FaasPlatform::new(Duration::ZERO));
            let busy: Handler = Arc::new(|b: &Bytes| {
                std::thread::sleep(Duration::from_millis(HANDLER_MS));
                Ok(b.clone())
            });
            platform.register(FunctionSpec::new("grad", 1024, busy)).unwrap();
            let executor = Arc::new(Executor::new(4));
            let scheduler = BranchScheduler::new(executor.clone(), true);
            let dispatch = |epoch: usize| {
                let mut pipe = PipelinedMap::new(
                    scheduler.clone(),
                    platform.clone(),
                    0,
                    "grad",
                    BRANCHES,
                    64,
                    RetryPolicy::default(),
                )
                .unwrap()
                .with_generation(epoch as u64);
                for _ in 0..BRANCHES {
                    pipe.submit(Bytes::from_static(b"b"), None);
                }
                pipe
            };
            let collect = |mut pipe: PipelinedMap| {
                while pipe.next_output().is_some() {}
                pipe.finish().unwrap()
            };
            let t0 = std::time::Instant::now();
            if cross_epoch {
                // the peer shape: dispatch e+1 right after e's update,
                // then pay the coordination gap while e+1 executes
                let mut pending = dispatch(1);
                for epoch in 1..=EPOCHS {
                    std::thread::sleep(Duration::from_millis(COORD_MS));
                    collect(pending);
                    pending = dispatch(epoch + 1);
                }
                collect(pending);
            } else {
                for epoch in 1..=EPOCHS + 1 {
                    let pipe = dispatch(epoch);
                    collect(pipe);
                    if epoch <= EPOCHS {
                        std::thread::sleep(Duration::from_millis(COORD_MS));
                    }
                }
            }
            t0.elapsed()
        };
        let pipelined_wall = run(false);
        let cross_wall = run(true);
        // (peak in-flight generations is not printed here: with a
        // single offloader each epoch is fully collected before the
        // next dispatch, so cluster-level generation overlap — peers
        // skewed across the boundary — is not visible in this harness)
        let waves = (BRANCHES / 4) as u64;
        let ideal = Duration::from_millis((EPOCHS as u64 + 1) * HANDLER_MS * waves);
        println!(
            "epoch_boundary: pipelined {pipelined_wall:?} (idle ≈ {:?}) vs cross-epoch \
             {cross_wall:?} (idle ≈ {:?}) over {} boundaries of {COORD_MS} ms coordination",
            pipelined_wall.saturating_sub(ideal),
            cross_wall.saturating_sub(ideal),
            EPOCHS,
        );
    }

    // staged vs pipelined epoch dispatch: 12 branches, a 8 ms simulated
    // upload per batch on the caller thread, a 50 ms handler, 4-thread
    // pool — the pipelined path hides later handler waves behind the
    // uploads (modeled outputs are identical; only measured time moves)
    let mut b = Bench::new("pipeline").with_samples(1, 5);
    for &pipelined in &[false, true] {
        let name = if pipelined {
            "epoch_12x50ms_pipelined"
        } else {
            "epoch_12x50ms_staged"
        };
        let platform = Arc::new(FaasPlatform::new(Duration::ZERO));
        let busy: Handler = Arc::new(|b: &Bytes| {
            std::thread::sleep(Duration::from_millis(50));
            Ok(b.clone())
        });
        platform.register(FunctionSpec::new("grad", 1024, busy)).unwrap();
        let executor = Arc::new(Executor::new(4));
        let scheduler = BranchScheduler::new(executor.clone(), true);
        b.bench(name, move || {
            if pipelined {
                let mut pipe = PipelinedMap::new(
                    scheduler.clone(),
                    platform.clone(),
                    0,
                    "grad",
                    12,
                    64,
                    RetryPolicy::default(),
                )
                .unwrap();
                for _ in 0..12 {
                    std::thread::sleep(Duration::from_millis(8)); // "upload"
                    pipe.submit(Bytes::from_static(b"b"), None);
                    while pipe.poll_output().is_some() {}
                }
                while pipe.next_output().is_some() {}
                pipe.finish().unwrap()
            } else {
                let mut items = Vec::with_capacity(12);
                for _ in 0..12 {
                    std::thread::sleep(Duration::from_millis(8)); // "upload"
                    items.push(Bytes::from_static(b"b"));
                }
                let sm =
                    StateMachine::parallel_batches("bench", "grad", items, vec![], 64);
                sm.execute_with(&platform, &executor).unwrap()
            }
        });
    }

    // real execution (needs artifacts)
    let dir = if std::path::Path::new("artifacts/manifest.json").exists() {
        "artifacts"
    } else if std::path::Path::new("../artifacts/manifest.json").exists() {
        "../artifacts"
    } else {
        eprintln!("SKIP real backend bench: run `make artifacts`");
        return;
    };
    let engine = Arc::new(Engine::new().unwrap());
    let base = TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs: 1,
        train_samples: 2 * 16 * 2,
        val_samples: 64,
        artifacts_dir: dir.into(),
        ..Default::default()
    };
    let mut b = Bench::new("real").with_samples(1, 2);
    for (name, backend, mode) in [
        ("instance_epoch", Backend::Instance, OffloadMode::Pipelined),
        ("serverless_epoch_staged", Backend::Serverless, OffloadMode::Staged),
        ("serverless_epoch_pipelined", Backend::Serverless, OffloadMode::Pipelined),
        ("serverless_epoch_cross_epoch", Backend::Serverless, OffloadMode::CrossEpoch),
    ] {
        let cfg = TrainConfig { backend, offload_mode: mode, ..base.clone() };
        let engine = engine.clone();
        b.bench(name, move || {
            Cluster::with_engine(cfg.clone(), engine.clone())
                .unwrap()
                .run()
                .unwrap()
        });
    }

    // warm vs cold data plane: a *cold* epoch pays the one-time batch
    // pack + upload before fanning out; a *warm* epoch reuses the
    // epoch-persistent refs and uploads only the params object. Store
    // puts per epoch are reported alongside the timings — the win the
    // zero-redundancy plane buys is both visible numbers shrinking.
    let runtime = Arc::new(
        ModelRuntime::load(engine.clone(), dir, "mini_squeezenet_mnist").unwrap(),
    );
    let data = SyntheticDataset::new(DatasetKind::Mnist, 11).generate(16 * 4);
    let batches = Batcher::new(16, 11).epoch_batches(&data, 0);
    let params = Arc::new(runtime.init_params().unwrap());
    let offloader = |store: &Arc<ObjectStore>| {
        ServerlessOffload::new(
            Arc::new(FaasPlatform::new(Duration::ZERO)),
            store.clone(),
            runtime.clone(),
            BranchScheduler::new(Arc::new(Executor::new(4)), true),
            Arc::new(DecodedCache::new(16)),
            0,
            1769,
            64,
            OffloadMode::Pipelined,
            true,
            2,
        )
        .unwrap()
    };

    let mut b = Bench::new("data_plane").with_samples(1, 4);
    {
        let batches = batches.clone();
        let params = params.clone();
        let runtime = runtime.clone();
        b.bench("epoch_cold_reupload_batches", move || {
            // fresh store + offloader: every "epoch" re-packs and
            // re-uploads the batch objects (the pre-PR shape)
            let store = Arc::new(ObjectStore::new());
            let off = ServerlessOffload::new(
                Arc::new(FaasPlatform::new(Duration::ZERO)),
                store.clone(),
                runtime.clone(),
                BranchScheduler::new(Arc::new(Executor::new(4)), true),
                Arc::new(DecodedCache::new(16)),
                0,
                1769,
                64,
                OffloadMode::Pipelined,
                true,
                2,
            )
            .unwrap();
            off.upload_batches(&batches).unwrap();
            off.compute_epoch(1, &params).unwrap()
        });
    }
    let warm_store = Arc::new(ObjectStore::new());
    let warm = Arc::new(offloader(&warm_store));
    warm.upload_batches(&batches).unwrap();
    {
        let warm = warm.clone();
        let params = params.clone();
        let mut epoch = 0usize;
        b.bench("epoch_warm_cached_batches", move || {
            epoch += 1;
            warm.compute_epoch(epoch, &params).unwrap()
        });
    }
    // per-epoch store put counts (one extra epoch each, counted exactly)
    let cold_store = Arc::new(ObjectStore::new());
    let cold = offloader(&cold_store);
    let p0 = cold_store.stats().0;
    cold.upload_batches(&batches).unwrap();
    cold.compute_epoch(1, &params).unwrap();
    let cold_puts = cold_store.stats().0 - p0;
    let p0 = warm_store.stats().0;
    warm.compute_epoch(1000, &params).unwrap();
    let warm_puts = warm_store.stats().0 - p0;
    println!(
        "data_plane: store puts per epoch — cold {} (batch upload + params + {} parked \
         grads), warm {} (params + parked grads only)",
        cold_puts,
        batches.len(),
        warm_puts,
    );
}
