//! Fig-3 bench: the serverless-vs-instance comparison at both scales —
//! modeled cloud cells (state-machine execution cost) and a real
//! two-peer PJRT run per backend.

use p2pless::config::{Backend, TrainConfig};
use p2pless::coordinator::Cluster;
use p2pless::harness::bench::{header, Bench};
use p2pless::harness::cloud_exps::fig3_cell;
use p2pless::perfmodel::PaperModel;
use p2pless::runtime::Engine;
use std::sync::Arc;

fn main() {
    header(
        "serverless_vs_instance",
        "modeled fig-3 cell computation + real two-peer runs per backend",
    );

    // cost of evaluating a modeled cell (orchestration overhead itself)
    let mut b = Bench::new("modeled").with_samples(3, 10);
    for &(peers, batch) in &[(4usize, 64usize), (12, 1024)] {
        b.bench(&format!("fig3_cell_p{peers}_b{batch}"), || {
            fig3_cell(PaperModel::Vgg11, peers, batch).unwrap()
        });
    }

    // real execution (needs artifacts)
    let dir = if std::path::Path::new("artifacts/manifest.json").exists() {
        "artifacts"
    } else if std::path::Path::new("../artifacts/manifest.json").exists() {
        "../artifacts"
    } else {
        eprintln!("SKIP real backend bench: run `make artifacts`");
        return;
    };
    let engine = Arc::new(Engine::new().unwrap());
    let base = TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs: 1,
        train_samples: 2 * 16 * 2,
        val_samples: 64,
        artifacts_dir: dir.into(),
        ..Default::default()
    };
    let mut b = Bench::new("real").with_samples(1, 2);
    for (name, backend) in [
        ("instance_epoch", Backend::Instance),
        ("serverless_epoch", Backend::Serverless),
    ] {
        let cfg = TrainConfig { backend, ..base.clone() };
        let engine = engine.clone();
        b.bench(name, move || {
            Cluster::with_engine(cfg.clone(), engine.clone())
                .unwrap()
                .run()
                .unwrap()
        });
    }
}
