//! Fig-3 bench: the serverless-vs-instance comparison at both scales —
//! modeled cloud cells (state-machine execution cost), the real
//! worker-pool fan-out at several thread counts, and a real two-peer
//! PJRT run per backend.

use p2pless::config::{Backend, TrainConfig};
use p2pless::coordinator::Cluster;
use p2pless::faas::{Executor, FaasPlatform, FunctionSpec, Handler, StateMachine};
use p2pless::harness::bench::{header, Bench};
use p2pless::harness::cloud_exps::fig3_cell;
use p2pless::perfmodel::PaperModel;
use p2pless::runtime::Engine;
use p2pless::util::Bytes;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    header(
        "serverless_vs_instance",
        "modeled fig-3 cells + real worker-pool fan-out + real two-peer runs per backend",
    );

    // cost of evaluating a modeled cell (orchestration overhead itself)
    let mut b = Bench::new("modeled").with_samples(3, 10);
    for &(peers, batch) in &[(4usize, 64usize), (12, 1024)] {
        b.bench(&format!("fig3_cell_p{peers}_b{batch}"), || {
            fig3_cell(PaperModel::Vgg11, peers, batch).unwrap()
        });
    }

    // the execution fabric itself: 16-branch fan-out of 5 ms handlers,
    // measured wall as the worker pool widens (modeled outputs are
    // identical at every size — only the measured wall should shrink)
    let mut b = Bench::new("fabric").with_samples(2, 8);
    for &threads in &[1usize, 2, 4, 8] {
        let platform = Arc::new(FaasPlatform::new(Duration::ZERO));
        let busy: Handler = Arc::new(|b: &Bytes| {
            std::thread::sleep(Duration::from_millis(5));
            Ok(b.clone())
        });
        platform.register(FunctionSpec::new("grad", 1024, busy)).unwrap();
        let pool = Executor::new(threads);
        b.bench(&format!("fanout_16x5ms_threads{threads}"), move || {
            let items: Vec<Bytes> = (0..16).map(|_| Bytes::from_static(b"b")).collect();
            let sm = StateMachine::parallel_batches("bench", "grad", items, vec![], 64);
            sm.execute_with(&platform, &pool).unwrap()
        });
    }

    // real execution (needs artifacts)
    let dir = if std::path::Path::new("artifacts/manifest.json").exists() {
        "artifacts"
    } else if std::path::Path::new("../artifacts/manifest.json").exists() {
        "../artifacts"
    } else {
        eprintln!("SKIP real backend bench: run `make artifacts`");
        return;
    };
    let engine = Arc::new(Engine::new().unwrap());
    let base = TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs: 1,
        train_samples: 2 * 16 * 2,
        val_samples: 64,
        artifacts_dir: dir.into(),
        ..Default::default()
    };
    let mut b = Bench::new("real").with_samples(1, 2);
    for (name, backend) in [
        ("instance_epoch", Backend::Instance),
        ("serverless_epoch", Backend::Serverless),
    ] {
        let cfg = TrainConfig { backend, ..base.clone() };
        let engine = engine.clone();
        b.bench(name, move || {
            Cluster::with_engine(cfg.clone(), engine.clone())
                .unwrap()
                .run()
                .unwrap()
        });
    }
}
