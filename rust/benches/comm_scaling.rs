//! Fig-4 bench: communication cost as the peer count grows — real
//! broker exchange of MobileNet-sized gradients between P threads, plus
//! the modeled full-scale times.
//!
//! Second act: the **wire-plane sweep** — bytes-on-wire and modeled
//! round wall for `none`/`qsgd:4`/`qsgd:16`/`topk:0.05` over the same
//! peer-count axis, emitted as `BENCH_wire_plane.json` (the committed
//! record; every value is integer-valued and content-independent, so
//! regeneration is byte-stable). `BENCH_WIRE_ONLY=1` (CI) skips the
//! threaded exchange and runs just the sweep.

use std::sync::Arc;

use p2pless::broker::{Broker, QueueMode};
use p2pless::compress::{codec_for, RawCodec};
use p2pless::config::Compression;
use p2pless::coordinator::GradientWire;
use p2pless::faas::pricing;
use p2pless::harness::bench::{header, Bench};
use p2pless::perfmodel::{self, paper_model, PaperModel};
use p2pless::store::ObjectStore;
use p2pless::util::{Json, Rng};

/// Integer pico-USD mirror of [`pricing`]'s transfer rate card, so the
/// committed JSON carries exact integers instead of float-formatted
/// dollars ($5e-6/PUT, $4e-7/GET, $0.02/GB = 20 pUSD/byte).
const PUT_E12: u64 = 5_000_000;
const GET_E12: u64 = 400_000;
const BYTE_E12: u64 = 20;

fn main() {
    let wire_only = std::env::var_os("BENCH_WIRE_ONLY").is_some();
    header(
        "comm_scaling",
        "one full gradient exchange round (publish + consume P-1 queues) over peer count",
    );
    let n = 250_000; // 1 MB gradients: in-process stand-in for the 10 MB MobileNet wire
    let mut rng = Rng::seed_from_u64(9);
    let grad: Vec<f32> = (0..n).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();

    if !wire_only {
        let mut b = Bench::new("exchange").with_samples(1, 5);
        for &peers in &[2usize, 4, 8, 12] {
            let grad = grad.clone();
            b.bench(&format!("round_{peers}_peers"), move || {
                let broker = Arc::new(Broker::default());
                let store = Arc::new(ObjectStore::new());
                for r in 0..peers {
                    broker
                        .declare(&Broker::gradient_queue(r), QueueMode::LatestOnly)
                        .unwrap();
                }
                let handles: Vec<_> = (0..peers)
                    .map(|r| {
                        let broker = broker.clone();
                        let store = store.clone();
                        let grad = grad.clone();
                        std::thread::spawn(move || {
                            let wire =
                                GradientWire::new(Arc::new(RawCodec), store, usize::MAX);
                            wire.publish(&broker, r, 1, &grad).unwrap();
                            let mut total = 0usize;
                            for p in 0..peers {
                                if p == r {
                                    continue;
                                }
                                let q = broker.get(&Broker::gradient_queue(p)).unwrap();
                                let m = q.await_epoch(1).unwrap();
                                total += wire.decode(&m.payload).unwrap().len();
                            }
                            total
                        })
                    })
                    .collect();
                for h in handles {
                    std::hint::black_box(h.join().unwrap());
                }
            });
        }

        println!("\nmodeled full-scale comm (fig 4 series):");
        for model in [PaperModel::Vgg11, PaperModel::MobilenetV3Small] {
            let spec = paper_model(model);
            for &peers in &[4usize, 8, 12] {
                let send = perfmodel::send_time(spec.gradient_bytes(), 1.0);
                let recv = perfmodel::recv_time(spec.gradient_bytes(), peers - 1, 1.0);
                println!(
                    "  {:<20} peers={peers:<3} send {:>8.2?}  recv {:>8.2?}",
                    spec.name, send, recv
                );
            }
        }
    }

    // ---- wire-plane sweep -----------------------------------------------
    // One store-mediated "round" among P peers: every peer parks its
    // gradient (P puts) and reads the other P-1 parks (P*(P-1) gets).
    // The per-object wire length is content-independent for every codec
    // here (it depends only on n / levels / frac), which is what makes
    // the committed JSON reproducible.
    println!("\nwire-plane sweep (serverless store path):");
    let raw_bytes = (n * 4) as u64; // what the plane counts as wire.bytes_raw
    let mut enc = Bench::new("wire_codec").with_samples(1, 3);
    let mut configs: Vec<Json> = Vec::new();
    for spec in ["none", "qsgd:4", "qsgd:16", "topk:0.05"] {
        let comp = Compression::parse(spec).unwrap();
        let wire_len = match comp {
            // `none` parks plain f32 bytes — no codec framing at all
            Compression::None => n * 4,
            _ => codec_for(comp, 7).encode(&grad).unwrap().len(),
        };
        let wire_pct = wire_len as u64 * 100 / raw_bytes;
        if spec == "qsgd:16" {
            // the PR's acceptance bar: qsgd:16 stays at or under 25%
            assert!(
                wire_pct <= 25,
                "qsgd:16 wire {wire_len} exceeds 25% of raw {raw_bytes}"
            );
        }
        // measured codec cost (stdout only — wall depends on the host,
        // so it stays out of the committed record)
        if comp != Compression::None {
            let g = grad.clone();
            enc.bench(&format!("encode_{spec}"), move || {
                codec_for(comp, 7).encode(&g).unwrap().len()
            });
        }
        for &peers in &[2usize, 4, 8, 12] {
            let puts = peers as u64;
            let gets = (peers * (peers - 1)) as u64;
            let round_bytes = (puts + gets) * wire_len as u64;
            // critical path per peer: own put, then P-1 sequential gets
            let wall = perfmodel::store_put_time(wire_len)
                + perfmodel::store_get_time(wire_len) * (peers as u32 - 1);
            let cost_e12 = puts * PUT_E12 + gets * GET_E12 + round_bytes * BYTE_E12;
            // the integer rate card must agree with the float model
            let usd = pricing::transfer_cost(round_bytes, puts, gets);
            assert!(
                (usd - cost_e12 as f64 / 1e12).abs() < 1e-9,
                "integer rate card drifted from pricing::transfer_cost"
            );
            println!(
                "  {spec:<10} peers={peers:<3} {wire_len:>8} B/grad ({wire_pct:>3}%) \
                 round {round_bytes:>10} B  modeled {wall:>9.2?}  ${:.6}",
                usd
            );
            let mut row = Json::obj();
            row.set("compression", spec)
                .set("peers", peers)
                .set("bytes_wire", wire_len)
                .set("wire_pct", wire_pct)
                .set("round_bytes_wire", round_bytes)
                .set("modeled_round_ns", wall.as_nanos() as u64)
                .set("transfer_cost_usd_e12", cost_e12);
            configs.push(row);
        }
    }
    let mut j = Json::obj();
    j.set("bench", "comm_scaling/wire_plane")
        .set("elems", n)
        .set("bytes_raw", raw_bytes)
        .set("configs", configs);
    if let Err(e) = std::fs::write("BENCH_wire_plane.json", j.to_string()) {
        eprintln!("could not write BENCH_wire_plane.json: {e}");
    } else {
        println!("\nwrote BENCH_wire_plane.json");
    }
}
