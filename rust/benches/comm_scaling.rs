//! Fig-4 bench: communication cost as the peer count grows — real
//! broker exchange of MobileNet-sized gradients between P threads, plus
//! the modeled full-scale times.
//!
//! Second act: the **wire-plane sweep** — bytes-on-wire and modeled
//! round wall for `none`/`qsgd:4`/`qsgd:16`/`topk:0.05` over the same
//! peer-count axis, emitted as `BENCH_wire_plane.json` (the committed
//! record; every value is integer-valued and content-independent, so
//! regeneration is byte-stable). `BENCH_WIRE_ONLY=1` (CI) skips the
//! threaded exchange and runs just the sweep.
//!
//! Third act: the **shard-plane sweep** — per-epoch bytes-on-wire, put
//! counts and modeled transfer cost for a 1 MB params object cut into
//! 20 shards, as the number of layers a generation actually touches
//! grows, driven through the real `store::shard` upload path and
//! emitted as `BENCH_shard_plane.json` (same byte-stability contract).
//! `BENCH_SHARD_ONLY=1` (CI) runs just this sweep.

use std::sync::Arc;

use p2pless::broker::{Broker, QueueMode};
use p2pless::compress::{codec_for, RawCodec};
use p2pless::config::Compression;
use p2pless::coordinator::GradientWire;
use p2pless::faas::pricing;
use p2pless::harness::bench::{header, Bench};
use p2pless::perfmodel::{self, paper_model, PaperModel};
use p2pless::store::shard::{
    upload_sharded, ShardPlane, ShardSpec, ShardState, SHARD_KIND_RAW,
};
use p2pless::store::{ObjectStore, PARAMS_BUCKET};
use p2pless::util::bytes::f32s_to_bytes;
use p2pless::util::{Bytes, Json, Rng};

/// Integer pico-USD mirror of [`pricing`]'s transfer rate card, so the
/// committed JSON carries exact integers instead of float-formatted
/// dollars ($5e-6/PUT, $4e-7/GET, $0.02/GB = 20 pUSD/byte).
const PUT_E12: u64 = 5_000_000;
const GET_E12: u64 = 400_000;
const BYTE_E12: u64 = 20;

fn main() {
    let wire_only = std::env::var_os("BENCH_WIRE_ONLY").is_some();
    let shard_only = std::env::var_os("BENCH_SHARD_ONLY").is_some();
    header(
        "comm_scaling",
        "one full gradient exchange round (publish + consume P-1 queues) over peer count",
    );
    let n = 250_000; // 1 MB gradients: in-process stand-in for the 10 MB MobileNet wire
    let mut rng = Rng::seed_from_u64(9);
    let grad: Vec<f32> = (0..n).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();

    if !wire_only && !shard_only {
        let mut b = Bench::new("exchange").with_samples(1, 5);
        for &peers in &[2usize, 4, 8, 12] {
            let grad = grad.clone();
            b.bench(&format!("round_{peers}_peers"), move || {
                let broker = Arc::new(Broker::default());
                let store = Arc::new(ObjectStore::new());
                for r in 0..peers {
                    broker
                        .declare(&Broker::gradient_queue(r), QueueMode::LatestOnly)
                        .unwrap();
                }
                let handles: Vec<_> = (0..peers)
                    .map(|r| {
                        let broker = broker.clone();
                        let store = store.clone();
                        let grad = grad.clone();
                        std::thread::spawn(move || {
                            let wire =
                                GradientWire::new(Arc::new(RawCodec), store, usize::MAX);
                            wire.publish(&broker, r, 1, &grad).unwrap();
                            let mut total = 0usize;
                            for p in 0..peers {
                                if p == r {
                                    continue;
                                }
                                let q = broker.get(&Broker::gradient_queue(p)).unwrap();
                                let m = q.await_epoch(1).unwrap();
                                total += wire.decode(&m.payload).unwrap().len();
                            }
                            total
                        })
                    })
                    .collect();
                for h in handles {
                    std::hint::black_box(h.join().unwrap());
                }
            });
        }

        println!("\nmodeled full-scale comm (fig 4 series):");
        for model in [PaperModel::Vgg11, PaperModel::MobilenetV3Small] {
            let spec = paper_model(model);
            for &peers in &[4usize, 8, 12] {
                let send = perfmodel::send_time(spec.gradient_bytes(), 1.0);
                let recv = perfmodel::recv_time(spec.gradient_bytes(), peers - 1, 1.0);
                println!(
                    "  {:<20} peers={peers:<3} send {:>8.2?}  recv {:>8.2?}",
                    spec.name, send, recv
                );
            }
        }
    }

    if !shard_only {
        wire_sweep(n, &grad);
    }
    if !wire_only {
        shard_sweep(n);
    }
}

/// The wire-plane sweep: one store-mediated "round" among P peers —
/// every peer parks its gradient (P puts) and reads the other P-1 parks
/// (P*(P-1) gets). The per-object wire length is content-independent
/// for every codec here (it depends only on n / levels / frac), which
/// is what makes the committed JSON reproducible.
fn wire_sweep(n: usize, grad: &[f32]) {
    println!("\nwire-plane sweep (serverless store path):");
    let raw_bytes = (n * 4) as u64; // what the plane counts as wire.bytes_raw
    let mut enc = Bench::new("wire_codec").with_samples(1, 3);
    let mut configs: Vec<Json> = Vec::new();
    for spec in ["none", "qsgd:4", "qsgd:16", "topk:0.05"] {
        let comp = Compression::parse(spec).unwrap();
        let wire_len = match comp {
            // `none` parks plain f32 bytes — no codec framing at all
            Compression::None => n * 4,
            _ => codec_for(comp, 7).encode(grad).unwrap().len(),
        };
        let wire_pct = wire_len as u64 * 100 / raw_bytes;
        if spec == "qsgd:16" {
            // the PR's acceptance bar: qsgd:16 stays at or under 25%
            assert!(
                wire_pct <= 25,
                "qsgd:16 wire {wire_len} exceeds 25% of raw {raw_bytes}"
            );
        }
        // measured codec cost (stdout only — wall depends on the host,
        // so it stays out of the committed record)
        if comp != Compression::None {
            let g = grad.to_vec();
            enc.bench(&format!("encode_{spec}"), move || {
                codec_for(comp, 7).encode(&g).unwrap().len()
            });
        }
        for &peers in &[2usize, 4, 8, 12] {
            let puts = peers as u64;
            let gets = (peers * (peers - 1)) as u64;
            let round_bytes = (puts + gets) * wire_len as u64;
            // critical path per peer: own put, then P-1 sequential gets
            let wall = perfmodel::store_put_time(wire_len)
                + perfmodel::store_get_time(wire_len) * (peers as u32 - 1);
            let cost_e12 = puts * PUT_E12 + gets * GET_E12 + round_bytes * BYTE_E12;
            // the integer rate card must agree with the float model
            let usd = pricing::transfer_cost(round_bytes, puts, gets);
            assert!(
                (usd - cost_e12 as f64 / 1e12).abs() < 1e-9,
                "integer rate card drifted from pricing::transfer_cost"
            );
            println!(
                "  {spec:<10} peers={peers:<3} {wire_len:>8} B/grad ({wire_pct:>3}%) \
                 round {round_bytes:>10} B  modeled {wall:>9.2?}  ${:.6}",
                usd
            );
            let mut row = Json::obj();
            row.set("compression", spec)
                .set("peers", peers)
                .set("bytes_wire", wire_len)
                .set("wire_pct", wire_pct)
                .set("round_bytes_wire", round_bytes)
                .set("modeled_round_ns", wall.as_nanos() as u64)
                .set("transfer_cost_usd_e12", cost_e12);
            configs.push(row);
        }
    }
    let mut j = Json::obj();
    j.set("bench", "comm_scaling/wire_plane")
        .set("elems", n)
        .set("bytes_raw", raw_bytes)
        .set("configs", configs);
    if let Err(e) = std::fs::write("BENCH_wire_plane.json", j.to_string()) {
        eprintln!("could not write BENCH_wire_plane.json: {e}");
    } else {
        println!("\nwrote BENCH_wire_plane.json");
    }
}

/// The shard-plane sweep: per-epoch bytes-on-wire, put counts and
/// modeled transfer cost for the same 1 MB params object cut into 20
/// shards, as the number of layers a generation actually touches (k)
/// grows. Each point drives the real [`upload_sharded`] path against a
/// fresh store — the put counts and manifest bytes in the committed
/// record are measured, not assumed — and every recorded value is exact
/// integer arithmetic over content-independent sizes, so regeneration
/// is byte-stable.
fn shard_sweep(n: usize) {
    println!("\nshard-plane sweep (k of L layers changed per epoch):");
    let layers = 20usize;
    assert_eq!(n % layers, 0, "equal shards keep the record's sizes exact");
    let shard_elems = n / layers;
    let shard_bytes = (shard_elems * 4) as u64;
    let raw_bytes = (n * 4) as u64;
    // the monolithic plane's steady-state epoch: one put, one
    // cluster-wide decode get, the whole params object on the wire
    let mono_cost_e12 = PUT_E12 + GET_E12 + raw_bytes * BYTE_E12;
    let mut rows: Vec<Json> = Vec::new();
    let mut manifest_bytes = 0u64;
    for &k in &[0usize, 1, 2, 5, 10, 20] {
        let store = ObjectStore::new();
        let plane = ShardPlane::new(ShardSpec::Count(layers), n, &[]).unwrap();
        let state = ShardState::new(plane.shard_count());
        let mut params: Vec<f32> = (0..n).map(|i| (i % 97) as f32 * 0.03125).collect();
        let up1 = upload_sharded(
            &plane,
            &state,
            &store,
            PARAMS_BUCKET,
            &params,
            1,
            SHARD_KIND_RAW,
            |_, slice| {
                let r =
                    store.put_dedup(PARAMS_BUCKET, Bytes::from(f32s_to_bytes(slice)), 1)?;
                Ok((r, slice.to_vec()))
            },
        )
        .unwrap();
        let puts_after_first = store.stats().0;
        // generation 2 touches the first element of each of the first k
        // shards — exactly k content hashes change
        for s in 0..k {
            params[s * shard_elems] += 1.0;
        }
        let up2 = upload_sharded(
            &plane,
            &state,
            &store,
            PARAMS_BUCKET,
            &params,
            2,
            SHARD_KIND_RAW,
            |_, slice| {
                let r =
                    store.put_dedup(PARAMS_BUCKET, Bytes::from(f32s_to_bytes(slice)), 2)?;
                Ok((r, slice.to_vec()))
            },
        )
        .unwrap();
        let puts = store.stats().0 - puts_after_first;
        assert_eq!(puts, (k + 1) as u64, "a k-of-L epoch puts k shards + 1 manifest");
        let bytes_saved = plane.bytes_saved();
        assert_eq!(bytes_saved, (layers - k) as u64 * shard_bytes);
        manifest_bytes = up2.manifest.size as u64;
        // 16-byte header + per entry: 33 fixed bytes + a 69-byte
        // ObjectRef wire (13-char bucket, 36-char key) — drift here
        // means the committed record's framing model went stale
        assert_eq!(manifest_bytes, 16 + layers as u64 * (33 + 69));
        let epoch_bytes = k as u64 * shard_bytes + manifest_bytes;
        // handler side: the manifest + each changed shard decodes once
        // cluster-wide; reused shards are DecodedCache hits, no get
        let gets = (k + 1) as u64;
        let cost_e12 = puts * PUT_E12 + gets * GET_E12 + epoch_bytes * BYTE_E12;
        // the integer rate card must agree with the float model
        let usd = pricing::transfer_cost(epoch_bytes, puts, gets);
        assert!(
            (usd - cost_e12 as f64 / 1e12).abs() < 1e-9,
            "integer rate card drifted from pricing::transfer_cost"
        );
        let verdict = if cost_e12 < mono_cost_e12 { "sharded" } else { "monolithic" };
        println!(
            "  k={k:<3} puts {puts:<3} {epoch_bytes:>8} B on wire  saved {bytes_saved:>8} B  \
             ${:.6} vs monolithic ${:.6} -> {verdict}",
            cost_e12 as f64 / 1e12,
            mono_cost_e12 as f64 / 1e12
        );
        let mut row = Json::obj();
        row.set("layers_changed", k)
            .set("puts", puts)
            .set("gets", gets)
            .set("epoch_bytes_wire", epoch_bytes)
            .set("bytes_saved", bytes_saved)
            .set("cost_usd_e12", cost_e12)
            .set("monolithic_cost_usd_e12", mono_cost_e12)
            .set("verdict", verdict);
        rows.push(row);
        // both holders release: reused objects live on generation 2's
        // retained references until the last release, then nothing leaks
        for r in up1.shards.iter().chain([&up1.manifest]) {
            store.release(r);
        }
        for r in up2.shards.iter().chain([&up2.manifest]) {
            store.release(r);
        }
        assert_eq!(store.total_objects(), 0, "shard sweep leaked store objects");
    }
    let mut j = Json::obj();
    j.set("bench", "comm_scaling/shard_plane")
        .set("elems", n)
        .set("bytes_raw", raw_bytes)
        .set("layers", layers)
        .set("shard_bytes", shard_bytes)
        .set("manifest_bytes", manifest_bytes)
        .set("rows", rows);
    if let Err(e) = std::fs::write("BENCH_shard_plane.json", j.to_string()) {
        eprintln!("could not write BENCH_shard_plane.json: {e}");
    } else {
        println!("\nwrote BENCH_shard_plane.json");
    }
}
