//! Fig-4 bench: communication cost as the peer count grows — real
//! broker exchange of MobileNet-sized gradients between P threads, plus
//! the modeled full-scale times.

use std::sync::Arc;

use p2pless::broker::{Broker, QueueMode};
use p2pless::compress::RawCodec;
use p2pless::coordinator::GradientWire;
use p2pless::harness::bench::{header, Bench};
use p2pless::perfmodel::{self, paper_model, PaperModel};
use p2pless::store::ObjectStore;
use p2pless::util::Rng;

fn main() {
    header(
        "comm_scaling",
        "one full gradient exchange round (publish + consume P-1 queues) over peer count",
    );
    let n = 250_000; // 1 MB gradients: in-process stand-in for the 10 MB MobileNet wire
    let mut rng = Rng::seed_from_u64(9);
    let grad: Vec<f32> = (0..n).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();

    let mut b = Bench::new("exchange").with_samples(1, 5);
    for &peers in &[2usize, 4, 8, 12] {
        let grad = grad.clone();
        b.bench(&format!("round_{peers}_peers"), move || {
            let broker = Arc::new(Broker::default());
            let store = Arc::new(ObjectStore::new());
            for r in 0..peers {
                broker
                    .declare(&Broker::gradient_queue(r), QueueMode::LatestOnly)
                    .unwrap();
            }
            let handles: Vec<_> = (0..peers)
                .map(|r| {
                    let broker = broker.clone();
                    let store = store.clone();
                    let grad = grad.clone();
                    std::thread::spawn(move || {
                        let wire =
                            GradientWire::new(Arc::new(RawCodec), store, usize::MAX);
                        wire.publish(&broker, r, 1, &grad).unwrap();
                        let mut total = 0usize;
                        for p in 0..peers {
                            if p == r {
                                continue;
                            }
                            let q = broker.get(&Broker::gradient_queue(p)).unwrap();
                            let m = q.await_epoch(1).unwrap();
                            total += wire.decode(&m.payload).unwrap().len();
                        }
                        total
                    })
                })
                .collect();
            for h in handles {
                std::hint::black_box(h.join().unwrap());
            }
        });
    }

    println!("\nmodeled full-scale comm (fig 4 series):");
    for model in [PaperModel::Vgg11, PaperModel::MobilenetV3Small] {
        let spec = paper_model(model);
        for &peers in &[4usize, 8, 12] {
            let send = perfmodel::send_time(spec.gradient_bytes(), 1.0);
            let recv = perfmodel::recv_time(spec.gradient_bytes(), peers - 1, 1.0);
            println!(
                "  {:<20} peers={peers:<3} send {:>8.2?}  recv {:>8.2?}",
                spec.name, send, recv
            );
        }
    }
}
