//! Table-I bench: a real 4-peer epoch with per-stage timing printed —
//! the benchmark form of `p2pless exp table1`.
//!
//! Needs `make artifacts`.

use std::sync::Arc;

use p2pless::config::TrainConfig;
use p2pless::coordinator::Cluster;
use p2pless::harness::bench::{header, Bench};
use p2pless::runtime::Engine;

fn main() {
    let dir = if std::path::Path::new("artifacts/manifest.json").exists() {
        "artifacts"
    } else if std::path::Path::new("../artifacts/manifest.json").exists() {
        "../artifacts"
    } else {
        eprintln!("SKIP stage_breakdown: run `make artifacts`");
        return;
    };
    header(
        "stage_breakdown",
        "full 4-peer epoch per model (Table I shape: compute dominates)",
    );
    let engine = Arc::new(Engine::new().unwrap());
    let mut b = Bench::new("epoch").with_samples(1, 2);
    for model in ["mini_squeezenet", "mini_mobilenet", "mini_vgg"] {
        let cfg = TrainConfig {
            model: model.into(),
            dataset: "mnist".into(),
            peers: 4,
            batch_size: 16,
            epochs: 1,
            train_samples: 4 * 16 * 2,
            val_samples: 64,
            artifacts_dir: dir.into(),
            ..Default::default()
        };
        let engine2 = engine.clone();
        let engine = engine.clone();
        let cfg2 = cfg.clone();
        b.bench(&format!("{model}_4peers"), move || {
            Cluster::with_engine(cfg2.clone(), engine.clone())
                .unwrap()
                .run()
                .unwrap()
        });
        // one verbose run for the stage table
        let rep = Cluster::with_engine(cfg, engine2.clone())
            .unwrap()
            .run()
            .unwrap();
        for (stage, s) in &rep.stages {
            if s.count > 0 {
                println!(
                    "    {:<24} total {:>10.3?} mean {:>10.3?}",
                    stage.to_string(),
                    s.total_wall,
                    s.mean_wall()
                );
            }
        }
    }
}
