"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/values; assert_allclose against the oracle is
THE core correctness signal for the kernels that end up inside every AOT
gradient artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import pallas_matmul, pmatmul
from compile.kernels.qsgd import qsgd_dequantize, qsgd_quantize

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape,
                              jnp.float32, lo, hi)


# ------------------------------------------------------------- matmul


@settings(**SETTINGS)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_small(m, k, n, seed):
    a = _rand(seed, (m, k))
    b = _rand(seed + 1, (k, n))
    got = pallas_matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (128, 128, 128),   # exactly one MXU tile
        (130, 7, 128),     # M padding
        (128, 9, 131),     # N padding
        (257, 300, 3),     # both + tall-skinny
        (1, 1, 1),
        (512, 64, 256),    # multi-tile grid
    ],
)
def test_matmul_matches_ref_tiles(m, k, n):
    a = _rand(m * 7 + n, (m, k))
    b = _rand(k * 3 + 1, (k, n))
    np.testing.assert_allclose(
        pallas_matmul(a, b), ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("bm,bn", [(8, 8), (32, 16), (128, 128)])
def test_matmul_block_shape_invariance(bm, bn):
    """Result must not depend on the tile decomposition."""
    a = _rand(5, (100, 33))
    b = _rand(6, (33, 70))
    np.testing.assert_allclose(
        pallas_matmul(a, b, block_m=bm, block_n=bn),
        ref.matmul_ref(a, b),
        rtol=1e-5, atol=1e-5,
    )


def test_pmatmul_gradients_match_autodiff():
    """custom VJP (pallas on bwd path) == jax autodiff of jnp.dot."""
    a = _rand(1, (17, 9))
    b = _rand(2, (9, 13))

    def f_pallas(a, b):
        return jnp.sum(jnp.sin(pmatmul(a, b)))

    def f_ref(a, b):
        return jnp.sum(jnp.sin(a @ b))

    ga_p, gb_p = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_p, ga_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gb_p, gb_r, rtol=1e-5, atol=1e-5)


def test_matmul_zero_and_identity():
    a = jnp.eye(16, dtype=jnp.float32)
    b = _rand(3, (16, 16))
    np.testing.assert_allclose(pallas_matmul(a, b), b, rtol=1e-6)
    z = jnp.zeros((16, 16), jnp.float32)
    np.testing.assert_allclose(pallas_matmul(z, b), z, atol=0)


# --------------------------------------------------------------- qsgd


@settings(**SETTINGS)
@given(n=st.integers(1, 3000), s=st.sampled_from([2, 4, 16, 64]),
       seed=st.integers(0, 2**31 - 1))
def test_qsgd_quantize_matches_ref(n, s, seed):
    v = _rand(seed, (n,), -5.0, 5.0)
    u = _rand(seed + 9, (n,), 0.0, 1.0)
    q, norm = qsgd_quantize(v, u, s)
    q_ref, norm_ref = ref.qsgd_quantize_ref(v, u, s)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(norm, norm_ref, rtol=1e-6)


@settings(**SETTINGS)
@given(n=st.integers(1, 3000), s=st.sampled_from([4, 16]),
       seed=st.integers(0, 2**31 - 1))
def test_qsgd_roundtrip_bounded_error(n, s, seed):
    """|dequant(quant(v)) - v| <= norm/s elementwise (one level step)."""
    v = _rand(seed, (n,), -1.0, 1.0)
    u = _rand(seed + 9, (n,), 0.0, 1.0)
    q, norm = qsgd_quantize(v, u, s)
    vhat = qsgd_dequantize(q, norm, s)
    np.testing.assert_allclose(vhat, ref.qsgd_dequantize_ref(q, norm, s),
                               rtol=1e-6, atol=1e-7)
    assert np.max(np.abs(np.asarray(vhat - v))) <= float(norm[0]) / s + 1e-5


def test_qsgd_unbiased():
    """E[Q(v)] = v: average many independent quantizations."""
    n, s, reps = 256, 4, 400
    v = _rand(7, (n,), -1.0, 1.0)
    key = jax.random.PRNGKey(123)
    acc = jnp.zeros_like(v)
    for i in range(reps):
        key, sub = jax.random.split(key)
        u = jax.random.uniform(sub, (n,), jnp.float32)
        q, norm = qsgd_quantize(v, u, s)
        acc = acc + qsgd_dequantize(q, norm, s)
    mean = acc / reps
    # std of the estimator is O(norm/s/sqrt(reps)); allow 5 sigma
    norm = float(jnp.linalg.norm(v))
    tol = 5 * norm / s / np.sqrt(reps)
    assert float(jnp.max(jnp.abs(mean - v))) < tol


def test_qsgd_zero_vector():
    v = jnp.zeros((64,), jnp.float32)
    u = jnp.full((64,), 0.5, jnp.float32)
    q, norm = qsgd_quantize(v, u, 16)
    assert float(norm[0]) == 0.0
    np.testing.assert_array_equal(np.asarray(q), np.zeros(64, np.int32))
    np.testing.assert_array_equal(np.asarray(qsgd_dequantize(q, norm, 16)),
                                  np.zeros(64, np.float32))


def test_qsgd_levels_in_range():
    v = _rand(11, (1000,), -3.0, 3.0)
    u = _rand(12, (1000,), 0.0, 1.0)
    s = 8
    q, _ = qsgd_quantize(v, u, s)
    assert int(jnp.max(jnp.abs(q))) <= s + 1  # |v_i|<=norm => level <= s (+u<1)
