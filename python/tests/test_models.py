"""L2 correctness: model shapes, gradient sanity, pallas/jnp agreement,
and that a few SGD steps reduce loss (trainability)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import DATASETS, MODELS, Model

BATCH = 8


def _batch(m: Model, seed=0):
    h, w, c = m.input_shape
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (BATCH, h, w, c), jnp.float32)
    y = jax.random.randint(ky, (BATCH,), 0, m.nclass, jnp.int32)
    return x, y


@pytest.fixture(scope="module")
def models():
    out = {}
    for name in MODELS:
        for ds in DATASETS:
            out[(name, ds)] = Model(name, ds)
    return out


@pytest.mark.parametrize("name", MODELS)
@pytest.mark.parametrize("ds", list(DATASETS))
def test_forward_shape(models, name, ds):
    m = models[(name, ds)]
    flat = m.init_flat(0)
    assert flat.shape == (m.param_count,)
    x, _ = _batch(m)
    (logits,) = m.forward(flat, x)
    assert logits.shape == (BATCH, m.nclass)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", MODELS)
def test_grad_step_finite_and_nonzero(models, name):
    m = models[(name, "mnist")]
    flat = m.init_flat(1)
    x, y = _batch(m, 1)
    loss, g = jax.jit(m.grad_step)(flat, x, y)
    assert g.shape == flat.shape
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.linalg.norm(g)) > 1e-6


@pytest.mark.parametrize("name", MODELS)
def test_pallas_and_jnp_paths_agree(models, name):
    """The L1 kernel inside the model must not change the math."""
    m = models[(name, "mnist")]
    flat = m.init_flat(2)
    x, y = _batch(m, 2)
    l1, g1 = jax.jit(m.grad_step)(flat, x, y)
    l2, g2 = jax.jit(lambda p, x, y: m.grad_step(p, x, y, use_pallas=False))(
        flat, x, y)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-6)


def test_apply_update_is_sgd(models):
    m = models[("mini_squeezenet", "mnist")]
    flat = m.init_flat(3)
    g = jnp.ones_like(flat)
    lr = jnp.array([0.1], jnp.float32)
    (new,) = m.apply_update(flat, g, lr)
    np.testing.assert_allclose(new, flat - 0.1, rtol=1e-6)


@pytest.mark.parametrize("name", MODELS)
def test_few_sgd_steps_reduce_loss(models, name):
    m = models[(name, "mnist")]
    flat = m.init_flat(4)
    x, y = _batch(m, 4)
    step = jax.jit(m.grad_step)
    lr = jnp.array([0.05], jnp.float32)
    loss0, _ = step(flat, x, y)
    for _ in range(10):
        _, g = step(flat, x, y)
        (flat,) = m.apply_update(flat, g, lr)
    loss1, _ = step(flat, x, y)
    assert float(loss1) < float(loss0), (float(loss0), float(loss1))


def test_evaluate_counts(models):
    m = models[("mini_vgg", "cifar")]
    flat = m.init_flat(5)
    x, y = _batch(m, 5)
    loss, correct = m.evaluate(flat, x, y)
    assert bool(jnp.isfinite(loss))
    assert 0.0 <= float(correct) <= BATCH


def test_param_counts_ordering():
    """VGG mini must dominate (mirrors the paper's 132.9M vs 1.2/2.5M)."""
    sq = Model("mini_squeezenet", "mnist").param_count
    mb = Model("mini_mobilenet", "mnist").param_count
    vg = Model("mini_vgg", "mnist").param_count
    assert vg > 5 * max(sq, mb)


def test_param_spec_covers_flat_vector():
    m = Model("mini_mobilenet", "cifar")
    spec = m.params.spec_json()
    total = sum(e["size"] for e in spec)
    assert total == m.param_count
    # offsets are contiguous and non-overlapping
    off = 0
    for e in spec:
        assert e["offset"] == off
        off += e["size"]
