"""AOT path: lowering produces parseable HLO text with the right entry
signature, and the manifest round-trips."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.model import Model


def test_to_hlo_text_basic():
    def fn(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    low = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(low)
    assert "ENTRY" in text
    assert "f32[4,4]" in text


def test_to_hlo_text_pallas_interpret_lowering():
    """Pallas interpret=True must lower to plain HLO (no custom-call)."""
    from compile.kernels.matmul import pallas_matmul

    spec = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    low = jax.jit(lambda a, b: (pallas_matmul(a, b),)).lower(spec, spec)
    text = aot.to_hlo_text(low)
    assert "ENTRY" in text
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_grad_artifact_signature():
    m = Model("mini_squeezenet", "mnist")
    pspec = jax.ShapeDtypeStruct((m.param_count,), jnp.float32)
    xs = jax.ShapeDtypeStruct((4, 28, 28, 1), jnp.float32)
    ys = jax.ShapeDtypeStruct((4,), jnp.int32)
    low = jax.jit(m.grad_step).lower(pspec, xs, ys)
    text = aot.to_hlo_text(low)
    assert "ENTRY" in text
    # outputs: tuple of (loss scalar, grads vector)
    assert f"f32[{m.param_count}]" in text


def test_grad_stacked_matches_per_lane_grad_step():
    """Stacked lowering keeps lanes independent: lane i's outputs equal a
    plain grad_step over micro-batch i, and nothing folds across lanes."""
    m = Model("mini_squeezenet", "mnist")
    flat = m.init_flat(seed=0)
    k, b = 3, 2
    key = jax.random.PRNGKey(7)
    xs = jax.random.normal(key, (k, b, 28, 28, 1), jnp.float32)
    ys = jnp.arange(k * b, dtype=jnp.int32).reshape(k, b) % m.nclass
    losses, grads = m.grad_stacked(flat, xs, ys)
    assert losses.shape == (k,)
    assert grads.shape == (k, m.param_count)
    for i in range(k):
        loss_i, g_i = m.grad_step(flat, xs[i], ys[i])
        assert jnp.allclose(losses[i], loss_i)
        assert jnp.allclose(grads[i], g_i)


def test_grad_stacked_artifact_signature():
    m = Model("mini_squeezenet", "mnist")
    k, b = 4, 4
    pspec = jax.ShapeDtypeStruct((m.param_count,), jnp.float32)
    xs = jax.ShapeDtypeStruct((k, b, 28, 28, 1), jnp.float32)
    ys = jax.ShapeDtypeStruct((k, b), jnp.int32)
    low = jax.jit(m.grad_stacked).lower(pspec, xs, ys)
    text = aot.to_hlo_text(low)
    assert "ENTRY" in text
    # per-branch outputs: losses f32[k] + grads f32[k, P]
    assert f"f32[{k},{m.param_count}]" in text


@pytest.mark.slow
def test_quick_aot_writes_manifest(tmp_path, monkeypatch):
    monkeypatch.setattr(
        "sys.argv",
        ["aot", "--out", str(tmp_path), "--models", "mini_squeezenet",
         "--datasets", "mnist", "--quick"],
    )
    aot.main()
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["version"] == 2
    entry = man["models"]["mini_squeezenet_mnist"]
    # --quick still ships the smallest stacked artifact for CI smoke
    for rel in [entry["artifacts"]["grad"]["16"], entry["artifacts"]["update"],
                entry["artifacts"]["grad_stacked"]["16"]["4"],
                entry["init_params"], man["qsgd"]["encode"]]:
        assert os.path.exists(tmp_path / rel)
    # init params file has exactly param_count f32s
    size = os.path.getsize(tmp_path / entry["init_params"])
    assert size == 4 * entry["param_count"]
