"""Build-time compile path: L2 JAX models + L1 Pallas kernels -> AOT HLO.

Never imported at runtime; the rust coordinator consumes only the
artifacts this package emits (see aot.py).
"""
