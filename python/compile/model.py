"""L2: the paper's three CNN workloads as JAX models over flat parameters.

The paper trains SqueezeNet 1.1 (1.2 M params), MobileNetV3-Small (2.5 M)
and VGG-11 (132.9 M) on MNIST/CIFAR-10 with PyTorch on EC2/Lambda. Here
each family is reproduced as a *structurally faithful mini* — fire
modules, inverted residuals with SE, plain conv stacks — sized to train
on the CPU-PJRT testbed (full-scale analytic specs used by the cost/time
model live in rust/src/perfmodel). See DESIGN.md substitution table.

Every model exposes four AOT entry points, all over a single flat f32
parameter vector (the wire format peers exchange):

    grad_step(flat, x, y)      -> (loss, grads_flat)      # the hot spot
    apply_update(flat, g, lr)  -> (flat',)                 # SGD step
    evaluate(flat, x, y)       -> (loss, correct_count)
    forward(flat, x)           -> (logits,)

All conv/dense matmuls route through the L1 Pallas kernel (im2col x
weight) unless use_pallas=False (ablation artifacts).
"""

import functools

import jax
import jax.numpy as jnp

from . import nn
from .nn import ParamSet

# dataset name -> (H, W, C, nclass)
DATASETS = {
    "mnist": (28, 28, 1, 10),
    "cifar": (32, 32, 3, 10),
}

MODELS = ("mini_squeezenet", "mini_mobilenet", "mini_vgg")


# --------------------------------------------------------------- builders


def _build_mini_vgg(p: ParamSet, cin: int, nclass: int, hw: int):
    """VGG-style conv stack: conv-relu-pool x3 + two dense layers."""
    widths = (16, 32, 64)
    c = cin
    for i, w in enumerate(widths):
        nn.declare_conv(p, f"conv{i}", 3, 3, c, w)
        c = w
    final_hw = hw // 2 // 2 // 2
    feat = final_hw * final_hw * widths[-1]
    nn.declare_dense(p, "fc1", feat, 128)
    nn.declare_dense(p, "fc2", 128, nclass)

    def apply(flat, x, use_pallas=True):
        c2 = cin
        for i, w in enumerate(widths):
            x = nn.conv2d(p, flat, x, f"conv{i}", 3, 3, c2, w,
                          use_pallas=use_pallas)
            x = nn.relu(x)
            x = nn.maxpool(x)
            c2 = w
        x = x.reshape(x.shape[0], -1)
        x = nn.relu(nn.dense(p, flat, x, "fc1", feat, 128, use_pallas))
        return nn.dense(p, flat, x, "fc2", 128, nclass, use_pallas)

    return apply


def _build_mini_squeezenet(p: ParamSet, cin: int, nclass: int, hw: int):
    """SqueezeNet-style: stem conv, two fire modules, GAP classifier.

    A fire module squeezes to s 1x1 channels then expands to e 1x1 + e 3x3
    (concatenated) — exactly SqueezeNet 1.1's block at reduced width."""
    fires = [
        ("fire1", 16, 8, 16),   # (name, cin, squeeze, expand)
        ("fire2", 32, 8, 16),
        ("fire3", 32, 16, 32),
    ]
    nn.declare_conv(p, "stem", 3, 3, cin, 16)
    for name, fc, s, e in fires:
        nn.declare_conv(p, f"{name}/squeeze", 1, 1, fc, s)
        nn.declare_conv(p, f"{name}/e1", 1, 1, s, e)
        nn.declare_conv(p, f"{name}/e3", 3, 3, s, e)
    nn.declare_conv(p, "head", 1, 1, 64, nclass)

    def fire(flat, x, name, fc, s, e, up):
        z = nn.relu(nn.conv2d(p, flat, x, f"{name}/squeeze", 1, 1, fc, s,
                              use_pallas=up))
        a = nn.relu(nn.conv2d(p, flat, z, f"{name}/e1", 1, 1, s, e,
                              use_pallas=up))
        b = nn.relu(nn.conv2d(p, flat, z, f"{name}/e3", 3, 3, s, e,
                              use_pallas=up))
        return jnp.concatenate([a, b], axis=-1)

    def apply(flat, x, use_pallas=True):
        x = nn.relu(nn.conv2d(p, flat, x, "stem", 3, 3, cin, 16,
                              use_pallas=use_pallas))
        x = nn.maxpool(x)
        x = fire(flat, x, "fire1", 16, 8, 16, use_pallas)
        x = fire(flat, x, "fire2", 32, 8, 16, use_pallas)
        x = nn.maxpool(x)
        x = fire(flat, x, "fire3", 32, 16, 32, use_pallas)
        x = nn.conv2d(p, flat, x, "head", 1, 1, 64, nclass,
                      use_pallas=use_pallas)
        return nn.global_avgpool(x)

    return apply


def _build_mini_mobilenet(p: ParamSet, cin: int, nclass: int, hw: int):
    """MobileNetV3-Small-style: stem, inverted residual blocks with
    depthwise conv + SE + hardswish, GAP + dense classifier."""
    # (name, cin, expand, cout, stride, use_se)
    blocks = [
        ("ir1", 16, 32, 16, 1, True),
        ("ir2", 16, 48, 24, 2, False),
        ("ir3", 24, 64, 24, 1, True),
    ]
    nn.declare_conv(p, "stem", 3, 3, cin, 16)
    for name, bc, ec, oc, _, use_se in blocks:
        nn.declare_conv(p, f"{name}/expand", 1, 1, bc, ec)
        nn.declare_depthwise(p, f"{name}/dw", 3, 3, ec)
        if use_se:
            nn.declare_se(p, f"{name}/se", ec)
        nn.declare_conv(p, f"{name}/project", 1, 1, ec, oc)
    nn.declare_dense(p, "fc1", 24, 64)
    nn.declare_dense(p, "fc2", 64, nclass)

    def apply(flat, x, use_pallas=True):
        x = nn.hardswish(nn.conv2d(p, flat, x, "stem", 3, 3, cin, 16,
                                   stride=2, use_pallas=use_pallas))
        for name, bc, ec, oc, stride, use_se in blocks:
            inp = x
            z = nn.hardswish(nn.conv2d(p, flat, x, f"{name}/expand", 1, 1,
                                       bc, ec, use_pallas=use_pallas))
            z = nn.hardswish(nn.depthwise2d(p, flat, z, f"{name}/dw", 3, 3,
                                            ec, stride=stride))
            if use_se:
                z = nn.se_block(p, flat, z, f"{name}/se", ec,
                                use_pallas=use_pallas)
            z = nn.conv2d(p, flat, z, f"{name}/project", 1, 1, ec, oc,
                          use_pallas=use_pallas)
            if stride == 1 and bc == oc:
                z = z + inp
            x = z
        x = nn.global_avgpool(x)
        x = nn.hardswish(nn.dense(p, flat, x, "fc1", 24, 64, use_pallas))
        return nn.dense(p, flat, x, "fc2", 64, nclass, use_pallas)

    return apply


_BUILDERS = {
    "mini_vgg": _build_mini_vgg,
    "mini_squeezenet": _build_mini_squeezenet,
    "mini_mobilenet": _build_mini_mobilenet,
}


class Model:
    """A model family instantiated for a dataset: spec + AOT entry points."""

    def __init__(self, name: str, dataset: str):
        if name not in _BUILDERS:
            raise ValueError(f"unknown model {name!r}")
        h, w, c, nclass = DATASETS[dataset]
        self.name, self.dataset = name, dataset
        self.input_shape = (h, w, c)
        self.nclass = nclass
        self.params = ParamSet()
        self._apply = _BUILDERS[name](self.params, c, nclass, h)

    @property
    def param_count(self) -> int:
        return self.params.total

    def init_flat(self, seed: int = 0):
        return self.params.init_flat(jax.random.PRNGKey(seed))

    # ---- AOT entry points (each returns a tuple: artifacts are tuples) --

    def forward(self, flat, x, use_pallas=True):
        return (self._apply(flat, x, use_pallas=use_pallas),)

    def loss(self, flat, x, y, use_pallas=True):
        logits = self._apply(flat, x, use_pallas=use_pallas)
        return nn.softmax_xent(logits, y, self.nclass)

    def grad_step(self, flat, x, y, use_pallas=True):
        """(loss, flat gradient) — the per-batch hot spot peers offload."""
        loss, g = jax.value_and_grad(
            functools.partial(self.loss, use_pallas=use_pallas)
        )(flat, x, y)
        return loss, g

    def grad_stacked(self, flat, xs, ys, use_pallas=True):
        """k independent grad_steps over stacked micro-batches.

        xs is (k, B, H, W, C), ys is (k, B); every lane shares the same
        flat params. Returns (losses[k], grads[k, P]) with NO cross-lane
        reduction, so the runtime can split the outputs back to the k
        callers exactly as if each had executed its own grad artifact.
        The loop is unrolled at trace time (k is a compile-time constant
        baked into the artifact name), keeping each lane's computation
        graph identical to the single-batch grad_step lowering.
        """
        losses, grads = [], []
        for i in range(xs.shape[0]):
            loss, g = self.grad_step(flat, xs[i], ys[i], use_pallas=use_pallas)
            losses.append(loss)
            grads.append(g)
        return jnp.stack(losses), jnp.stack(grads)

    def apply_update(self, flat, grads, lr):
        """Plain SGD: theta <- theta - lr * g (paper Alg. 1 update)."""
        return (flat - lr.reshape(()) * grads,)

    def evaluate(self, flat, x, y, use_pallas=True):
        logits = self._apply(flat, x, use_pallas=use_pallas)
        return (
            nn.softmax_xent(logits, y, self.nclass),
            nn.accuracy_count(logits, y),
        )
