"""L1: Pallas QSGD stochastic-quantization kernel.

QSGD (Alistarh et al., NeurIPS'17) is the compression scheme the paper
uses on the gradient-exchange path (SSIII-B.4). For a gradient vector v
with l2 norm ||v|| and s quantization levels:

    Q_s(v_i) = ||v|| * sgn(v_i) * xi_i / s
    xi_i     = floor(|v_i| / ||v|| * s + u_i),   u_i ~ U[0, 1)

i.e. stochastic rounding of |v_i|/||v|| * s to an integer level in
[0, s]. E[Q_s(v)] = v (unbiased).

The kernel is the elementwise (VPU-shaped) part: given the pre-scaled
tensor `scaled = v * s / ||v||` and uniform noise `u`, it emits signed
integer levels. Norm reduction and the final scale live in jnp (L2) —
keeping the kernel a pure 2-D-blocked map mirrors how the quantizer would
tile on real hardware. int32 output: wide enough for any s, and the rust
codec packs levels down to i8 on the wire when s <= 127.

interpret=True for CPU-PJRT executability (see matmul.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256  # elements per grid step along the flattened axis (x8 lanes)
LANES = 8


def _quantize_kernel(scaled_ref, u_ref, o_ref):
    s = scaled_ref[...]
    level = jnp.floor(jnp.abs(s) + u_ref[...])
    o_ref[...] = (jnp.sign(s) * level).astype(jnp.int32)


def _dequantize_kernel(q_ref, scale_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[...]


def _to_blocks(x, block, lanes):
    """Flatten + zero-pad to a (rows, lanes) grid-friendly 2-D layout."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    per = block * lanes
    rem = (-n) % per
    if rem:
        flat = jnp.pad(flat, (0, rem))
    return flat.reshape(-1, lanes), n


@functools.partial(jax.jit, static_argnames=("s",))
def qsgd_quantize(v, u, s: int = 16):
    """Quantize `v` to integer levels. Returns (levels int32, norm f32[1]).

    `u` must be uniform [0,1) noise of v's shape (passed in — the AOT
    artifact has no ambient RNG; the rust coordinator supplies the bits).
    """
    norm = jnp.sqrt(jnp.sum(v.astype(jnp.float32) ** 2))
    # Guard the zero vector: scale of 0 keeps all levels at 0.
    inv = jnp.where(norm > 0.0, s / norm, 0.0)
    scaled2d, n = _to_blocks(v.astype(jnp.float32) * inv, BLOCK, LANES)
    u2d, _ = _to_blocks(u.astype(jnp.float32), BLOCK, LANES)
    rows = scaled2d.shape[0]
    q = pl.pallas_call(
        _quantize_kernel,
        grid=(rows // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=True,
    )(scaled2d, u2d)
    return q.reshape(-1)[:n].reshape(v.shape), norm.reshape(1)


@functools.partial(jax.jit, static_argnames=("s",))
def qsgd_dequantize(q, norm, s: int = 16):
    """Inverse map: levels -> float gradient estimate (norm/s * q)."""
    scale = (norm.reshape(()) / s).astype(jnp.float32)
    q2d, n = _to_blocks(q, BLOCK, LANES)
    rows = q2d.shape[0]
    scale2d = jnp.broadcast_to(scale, (rows, LANES))
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(rows // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(q2d, scale2d)
    return out.reshape(-1)[:n].reshape(q.shape)
