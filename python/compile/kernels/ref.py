"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has its reference here; pytest asserts
allclose between kernel and oracle across a hypothesis-driven sweep of
shapes and values (python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    """Oracle for kernels.matmul.pallas_matmul."""
    return jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def qsgd_quantize_ref(v, u, s: int = 16):
    """Oracle for kernels.qsgd.qsgd_quantize (same stochastic bits `u`)."""
    v = v.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(v**2))
    inv = jnp.where(norm > 0.0, s / norm, 0.0)
    scaled = v * inv
    level = jnp.floor(jnp.abs(scaled) + u.astype(jnp.float32))
    return (jnp.sign(scaled) * level).astype(jnp.int32), norm.reshape(1)


def qsgd_dequantize_ref(q, norm, s: int = 16):
    """Oracle for kernels.qsgd.qsgd_dequantize."""
    return q.astype(jnp.float32) * (norm.reshape(()) / s)
