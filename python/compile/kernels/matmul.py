"""L1: Pallas tiled matmul kernel — the gradient-computation hot spot.

The paper's hot spot is per-batch CNN gradient computation on CPU-only
EC2/Lambda instances. For the TPU idiom required here, convolutions are
lowered to im2col x weight matmuls, and this kernel implements the matmul
as an MXU-shaped tiled kernel: a 2-D grid over (M, N) output tiles, the
full K dimension resident in VMEM per grid step (K <= a few thousand for
every conv/dense in the models, so a (block_m, K) + (K, block_n) +
(block_m, block_n) working set stays well under the ~16 MB VMEM budget —
see DESIGN.md SSPerf for the per-model footprint estimates).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode pallas lowers to plain HLO that the rust
runtime runs unmodified.

`pmatmul` wraps the kernel with a custom VJP (pallas_call is not
differentiable by itself) so the same kernel sits on the forward AND
backward paths of the AOT grad artifact:
    dA = dC @ B^T      dB = A^T @ dC
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tile. 128 matches the MXU systolic array edge; on the
# interpret/CPU path it simply becomes the HLO loop tile.
BLOCK_M = 128
BLOCK_N = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (block_m, K) x (K, block_n) tile product, f32 accumulate."""
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def pallas_matmul(a, b, block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """`a @ b` via the tiled Pallas kernel. a: [M, K], b: [K, N], f32.

    M and N are padded up to the tile size; K is carried whole into VMEM
    (the HBM<->VMEM schedule the paper's CPU code left to the cache
    hierarchy is expressed here by the BlockSpecs).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul inner dims mismatch: {a.shape} @ {b.shape}"
    bm = min(block_m, max(m, 1))
    bn = min(block_n, max(n, 1))
    ap = _pad_to(a, bm, 0)
    bp = _pad_to(b, bn, 1)
    mp, np_ = ap.shape[0], bp.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


@jax.custom_vjp
def pmatmul(a, b):
    """Differentiable pallas matmul (kernel on fwd and bwd paths)."""
    return pallas_matmul(a, b)


def _pmatmul_fwd(a, b):
    return pallas_matmul(a, b), (a, b)


def _pmatmul_bwd(res, g):
    a, b = res
    return pallas_matmul(g, b.T), pallas_matmul(a.T, g)


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)
