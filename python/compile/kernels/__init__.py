"""L1: Pallas kernels for the paper's compute hot spots.

- matmul: MXU-shaped tiled matmul behind every conv (im2col) and dense
  layer — the per-batch gradient-computation hot spot the paper offloads
  to serverless functions.
- qsgd: the QSGD stochastic quantizer used on the gradient-exchange path.
- ref: pure-jnp oracles for both.
"""
