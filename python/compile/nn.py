"""L2: minimal functional NN library over a single flattened f32 parameter
vector.

The rust coordinator treats model parameters as one opaque f32 vector (the
paper's peers exchange exactly that: a flat gradient). Every layer here
declares its parameters against a `ParamSet`, which assigns offsets into
the flat vector; `apply`-time code slices views back out. Gradients taken
with `jax.grad` w.r.t. the flat vector are therefore already in wire
format — no (un)flattening on the request path.

All matmuls (conv-as-im2col and dense) route through the L1 Pallas kernel
(`kernels.matmul.pmatmul`) unless `use_pallas=False` — that switch exists
only to emit the `_nopallas` ablation artifacts.
"""

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.matmul import pmatmul


@dataclass
class ParamEntry:
    name: str
    shape: tuple
    offset: int
    size: int
    init: str  # "he" | "zeros" | "ones"
    fan_in: int


@dataclass
class ParamSet:
    """Declares named parameters and assigns flat-vector offsets."""

    entries: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)
    total: int = 0

    def declare(self, name: str, shape, init: str = "he", fan_in: int = 0):
        if name in self.by_name:
            raise ValueError(f"duplicate param {name!r}")
        size = int(math.prod(shape))
        e = ParamEntry(name, tuple(shape), self.total, size, init, fan_in)
        self.entries.append(e)
        self.by_name[name] = e
        self.total += size
        return name

    def get(self, flat, name: str):
        e = self.by_name[name]
        return lax.dynamic_slice(flat, (e.offset,), (e.size,)).reshape(e.shape)

    def init_flat(self, key):
        """He-normal weights, zero biases, ones scales — as one flat vector."""
        chunks = []
        for e in self.entries:
            key, sub = jax.random.split(key)
            if e.init == "he":
                std = math.sqrt(2.0 / max(e.fan_in, 1))
                chunks.append(jax.random.normal(sub, (e.size,), jnp.float32) * std)
            elif e.init == "zeros":
                chunks.append(jnp.zeros((e.size,), jnp.float32))
            elif e.init == "ones":
                chunks.append(jnp.ones((e.size,), jnp.float32))
            else:
                raise ValueError(f"unknown init {e.init!r}")
        return jnp.concatenate(chunks) if chunks else jnp.zeros((0,), jnp.float32)

    def spec_json(self):
        return [
            dict(name=e.name, shape=list(e.shape), offset=e.offset, size=e.size)
            for e in self.entries
        ]


def _matmul(a, b, use_pallas: bool):
    if use_pallas:
        return pmatmul(a, b)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------- layers


def declare_conv(p: ParamSet, name, kh, kw, cin, cout):
    # weight layout matches conv_general_dilated_patches feature order:
    # (cin, kh, kw) flattened on the rows, cout on the columns.
    p.declare(f"{name}/w", (cin * kh * kw, cout), "he", fan_in=cin * kh * kw)
    p.declare(f"{name}/b", (cout,), "zeros")


def conv2d(p, flat, x, name, kh, kw, cin, cout, stride=1, padding="SAME",
           use_pallas=True):
    """conv = im2col patches x weight matrix (the Pallas hot path)."""
    w = p.get(flat, f"{name}/w")
    b = p.get(flat, f"{name}/b")
    patches = lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    bsz, h, wd, feat = patches.shape
    y = _matmul(patches.reshape(bsz * h * wd, feat), w, use_pallas)
    return y.reshape(bsz, h, wd, cout) + b


def declare_depthwise(p: ParamSet, name, kh, kw, ch):
    p.declare(f"{name}/w", (kh, kw, 1, ch), "he", fan_in=kh * kw)
    p.declare(f"{name}/b", (ch,), "zeros")


def depthwise2d(p, flat, x, name, kh, kw, ch, stride=1, padding="SAME"):
    """Depthwise conv. Not a matmul — stays on the jnp path (the FLOPs here
    are negligible next to the im2col matmuls; see DESIGN.md SSPerf)."""
    w = p.get(flat, f"{name}/w")
    b = p.get(flat, f"{name}/b")
    y = lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=ch,
    )
    return y + b


def declare_dense(p: ParamSet, name, din, dout):
    p.declare(f"{name}/w", (din, dout), "he", fan_in=din)
    p.declare(f"{name}/b", (dout,), "zeros")


def dense(p, flat, x, name, din, dout, use_pallas=True):
    w = p.get(flat, f"{name}/w")
    b = p.get(flat, f"{name}/b")
    return _matmul(x, w, use_pallas) + b


def relu(x):
    return jnp.maximum(x, 0.0)


def hardswish(x):
    return x * jnp.clip(x + 3.0, 0.0, 6.0) / 6.0


def hardsigmoid(x):
    return jnp.clip(x + 3.0, 0.0, 6.0) / 6.0


def maxpool(x, k=2, stride=2):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, k, k, 1), (1, stride, stride, 1), "VALID"
    )


def global_avgpool(x):
    return jnp.mean(x, axis=(1, 2))


def declare_se(p: ParamSet, name, ch, reduce=4):
    mid = max(ch // reduce, 4)
    declare_dense(p, f"{name}/fc1", ch, mid)
    declare_dense(p, f"{name}/fc2", mid, ch)
    return mid


def se_block(p, flat, x, name, ch, reduce=4, use_pallas=True):
    """Squeeze-and-excitation (MobileNetV3's SE module)."""
    mid = max(ch // reduce, 4)
    z = global_avgpool(x)
    z = relu(dense(p, flat, z, f"{name}/fc1", ch, mid, use_pallas))
    z = hardsigmoid(dense(p, flat, z, f"{name}/fc2", mid, ch, use_pallas))
    return x * z[:, None, None, :]


# ------------------------------------------------------------ objectives


def softmax_xent(logits, labels, nclass):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=1)
    return jnp.mean(logz - gold[:, 0])


def accuracy_count(logits, labels):
    """Number of correct top-1 predictions (f32 so outputs stay homogeneous)."""
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.sum((pred == labels.astype(jnp.int32)).astype(jnp.float32))
