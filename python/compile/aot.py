"""AOT compile path: lower every L2 entry point to HLO *text* + manifest.

This is the only place python touches the system. `make artifacts` runs it
once; the rust runtime (rust/src/runtime) then loads `artifacts/*.hlo.txt`
through `HloModuleProto::from_text_file` and executes via PJRT. Python is
never on the request path.

HLO TEXT, not `.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts per (model, dataset):
    grad_<m>_<ds>_b<B>.hlo.txt            (params, x, y) -> (loss, grads)
    grad_stacked_<m>_<ds>_b<B>x<k>.hlo.txt
                                          (params, xs[k], ys[k]) ->
                                          (losses[k], grads[k, P]):
                                          k micro-batches, per-branch
                                          outputs, no cross-lane reduction
    grad_<m>_<ds>_b<B>_nopallas.hlo.txt   ablation: jnp.dot instead of L1
    update_<m>_<ds>.hlo.txt               (params, grads, lr) -> (params',)
    eval_<m>_<ds>_b<B>.hlo.txt            (params, x, y) -> (loss, ncorrect)
    params_<m>_<ds>.f32                   initial parameters (raw LE f32)
Plus the QSGD kernel pair (encode/decode) for rust<->kernel
cross-validation, and manifest.json describing all of it.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels import qsgd
from .model import DATASETS, MODELS, Model

GRAD_BATCHES = (16, 64)
# stacking factors k for grad_stacked_bBxk artifacts: one XLA execution
# over k micro-batches with per-branch outputs (fused-group fast path)
STACK_FACTORS = (4, 8)
EVAL_BATCHES = (64, 256)
NOPALLAS_BATCHES = (64,)
QSGD_N = 4096
QSGD_S = 16


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir, name, text):
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {name} ({len(text)} chars)")
    return name


def lower_model(m: Model, out_dir: str, quick: bool):
    h, w, c = m.input_shape
    pspec = jax.ShapeDtypeStruct((m.param_count,), jnp.float32)
    lr_spec = jax.ShapeDtypeStruct((1,), jnp.float32)
    key = f"{m.name}_{m.dataset}"
    entry = dict(
        model=m.name,
        dataset=m.dataset,
        param_count=m.param_count,
        input=[h, w, c],
        nclass=m.nclass,
        artifacts=dict(grad={}, grad_stacked={}, grad_nopallas={}, eval={}),
        params_spec=m.params.spec_json(),
    )

    grad_batches = GRAD_BATCHES[:1] if quick else GRAD_BATCHES
    # --quick still emits the smallest stacked artifact so CI smoke can
    # exercise the stacked-dispatch path without a full compile
    stack_factors = STACK_FACTORS[:1] if quick else STACK_FACTORS
    eval_batches = EVAL_BATCHES[:1] if quick else EVAL_BATCHES
    nopallas = () if quick else NOPALLAS_BATCHES

    for b in grad_batches:
        xs = jax.ShapeDtypeStruct((b, h, w, c), jnp.float32)
        ys = jax.ShapeDtypeStruct((b,), jnp.int32)
        low = jax.jit(lambda p, x, y: m.grad_step(p, x, y)).lower(pspec, xs, ys)
        entry["artifacts"]["grad"][str(b)] = _write(
            out_dir, f"grad_{key}_b{b}.hlo.txt", to_hlo_text(low))
        entry["artifacts"]["grad_stacked"][str(b)] = {}
        for k in stack_factors:
            xss = jax.ShapeDtypeStruct((k, b, h, w, c), jnp.float32)
            yss = jax.ShapeDtypeStruct((k, b), jnp.int32)
            low = jax.jit(
                lambda p, x, y: m.grad_stacked(p, x, y)
            ).lower(pspec, xss, yss)
            entry["artifacts"]["grad_stacked"][str(b)][str(k)] = _write(
                out_dir, f"grad_stacked_{key}_b{b}x{k}.hlo.txt",
                to_hlo_text(low))
    for b in nopallas:
        xs = jax.ShapeDtypeStruct((b, h, w, c), jnp.float32)
        ys = jax.ShapeDtypeStruct((b,), jnp.int32)
        low = jax.jit(
            lambda p, x, y: m.grad_step(p, x, y, use_pallas=False)
        ).lower(pspec, xs, ys)
        entry["artifacts"]["grad_nopallas"][str(b)] = _write(
            out_dir, f"grad_{key}_b{b}_nopallas.hlo.txt", to_hlo_text(low))
    for b in eval_batches:
        xs = jax.ShapeDtypeStruct((b, h, w, c), jnp.float32)
        ys = jax.ShapeDtypeStruct((b,), jnp.int32)
        low = jax.jit(lambda p, x, y: m.evaluate(p, x, y)).lower(pspec, xs, ys)
        entry["artifacts"]["eval"][str(b)] = _write(
            out_dir, f"eval_{key}_b{b}.hlo.txt", to_hlo_text(low))

    gspec = jax.ShapeDtypeStruct((m.param_count,), jnp.float32)
    low = jax.jit(m.apply_update).lower(pspec, gspec, lr_spec)
    entry["artifacts"]["update"] = _write(
        out_dir, f"update_{key}.hlo.txt", to_hlo_text(low))

    init = np.asarray(m.init_flat(seed=0), dtype="<f4")
    fname = f"params_{key}.f32"
    init.tofile(os.path.join(out_dir, fname))
    entry["init_params"] = fname
    print(f"  wrote {fname} ({m.param_count} params)")
    return key, entry


def lower_qsgd(out_dir: str):
    vspec = jax.ShapeDtypeStruct((QSGD_N,), jnp.float32)
    uspec = jax.ShapeDtypeStruct((QSGD_N,), jnp.float32)
    qspec = jax.ShapeDtypeStruct((QSGD_N,), jnp.int32)
    nspec = jax.ShapeDtypeStruct((1,), jnp.float32)
    enc = jax.jit(lambda v, u: qsgd.qsgd_quantize(v, u, QSGD_S)).lower(vspec, uspec)
    dec = jax.jit(lambda q, n: (qsgd.qsgd_dequantize(q, n, QSGD_S),)).lower(qspec, nspec)
    return dict(
        n=QSGD_N,
        s=QSGD_S,
        encode=_write(out_dir, f"qsgd_encode_n{QSGD_N}.hlo.txt", to_hlo_text(enc)),
        decode=_write(out_dir, f"qsgd_decode_n{QSGD_N}.hlo.txt", to_hlo_text(dec)),
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for HLO text + manifest")
    ap.add_argument("--models", nargs="*", default=list(MODELS))
    ap.add_argument("--datasets", nargs="*", default=list(DATASETS))
    ap.add_argument("--quick", action="store_true",
                    help="smallest batch only, no ablation artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # schema v2: per-model artifacts.grad_stacked[batch][k] + the
    # top-level stack_factors list (v1 manifests have neither; the rust
    # loader accepts both and simply finds no stacked artifacts for v1)
    manifest = dict(version=2, models={}, grad_batches=list(GRAD_BATCHES),
                    stack_factors=list(STACK_FACTORS),
                    eval_batches=list(EVAL_BATCHES))
    for name in args.models:
        for ds in args.datasets:
            m = Model(name, ds)
            print(f"lowering {name} on {ds} ({m.param_count} params)")
            key, entry = lower_model(m, args.out, args.quick)
            manifest["models"][key] = entry
    manifest["qsgd"] = lower_qsgd(args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest.json: {len(manifest['models'])} model entries")


if __name__ == "__main__":
    main()
