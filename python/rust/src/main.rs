fn main() {}
