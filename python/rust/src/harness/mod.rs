//! stub
