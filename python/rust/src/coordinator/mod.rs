//! stub
