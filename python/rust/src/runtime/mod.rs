//! stub
