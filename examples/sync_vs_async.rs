//! Fig 6 as a runnable example: synchronous vs asynchronous P2P
//! training of the mini MobileNetV3 — the paper finds synchronous
//! converges faster and more stably (async consumes stale gradients).
//!
//!     cargo run --release --example sync_vs_async

use p2pless::config::{SyncMode, TrainConfig};
use p2pless::coordinator::Cluster;

fn main() -> anyhow::Result<()> {
    let base = TrainConfig {
        model: "mini_mobilenet".into(),
        dataset: "mnist".into(),
        peers: 4,
        batch_size: 16,
        epochs: 8,
        lr: 0.05,
        train_samples: 4 * 16 * 4,
        val_samples: 256,
        ..Default::default()
    };

    println!("sync vs async: {} peers, {} epochs", base.peers, base.epochs);
    let sync_cfg = TrainConfig { sync: SyncMode::Synchronous, ..base.clone() };
    let cluster = Cluster::new(sync_cfg)?;
    let engine = cluster.engine();
    let sync_rep = cluster.run()?;

    let async_cfg = TrainConfig { sync: SyncMode::Asynchronous, ..base };
    let async_rep = Cluster::with_engine(async_cfg, engine)?.run()?;

    println!("\nepoch  sync loss  sync acc   async loss  async acc");
    let n = sync_rep.val_curve.len().max(async_rep.val_curve.len());
    for i in 0..n {
        let s = sync_rep.val_curve.get(i);
        let a = async_rep.val_curve.get(i);
        println!(
            "{:>5}  {:>9}  {:>8}   {:>10}  {:>9}",
            i + 1,
            s.map(|v| format!("{:.4}", v.1)).unwrap_or_default(),
            s.map(|v| format!("{:.3}", v.2)).unwrap_or_default(),
            a.map(|v| format!("{:.4}", v.1)).unwrap_or_default(),
            a.map(|v| format!("{:.3}", v.2)).unwrap_or_default(),
        );
    }
    println!(
        "\nwall: sync {:?} vs async {:?}",
        sync_rep.wall, async_rep.wall
    );
    println!("paper fig 6: sync reaches higher accuracy in fewer epochs");
    Ok(())
}
