//! The paper's core comparison, twice:
//!
//! 1. REAL: the same training workload run with `Backend::Instance`
//!    (sequential per-batch PJRT gradients on each peer) and
//!    `Backend::Serverless` (per-batch fan-out through the Lambda/Step
//!    Functions substrate, gradients via S3, real GB-second billing).
//!    Losses must agree — the offload changes *where* gradients run,
//!    not the math.
//! 2. MODELED: the cloud-scale fig-3 cells with the calibrated
//!    perfmodel (full VGG-11 on t2.large vs Lambda).
//!
//!     cargo run --release --example serverless_vs_instance

use p2pless::config::{Backend, SyncMode, TrainConfig};
use p2pless::coordinator::Cluster;
use p2pless::harness::cloud_exps;
use p2pless::perfmodel::PaperModel;

fn main() -> anyhow::Result<()> {
    // ---------------- real execution, both backends ----------------
    let base = TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs: 2,
        lr: 0.05,
        train_samples: 2 * 16 * 4,
        val_samples: 64,
        sync: SyncMode::Synchronous,
        ..Default::default()
    };
    println!("[1/2] real execution: {} peers, {} epochs", base.peers, base.epochs);

    let inst_cfg = TrainConfig { backend: Backend::Instance, ..base.clone() };
    let cluster = Cluster::new(inst_cfg)?;
    let engine = cluster.engine();
    let inst = cluster.run()?;
    println!(
        "  instance  : wall {:?}, final val_loss {:?}",
        inst.wall,
        inst.final_val_loss()
    );

    let srv_cfg = TrainConfig { backend: Backend::Serverless, ..base };
    let srv = Cluster::with_engine(srv_cfg, engine)?.run()?;
    println!(
        "  serverless: wall {:?}, final val_loss {:?}",
        srv.wall,
        srv.final_val_loss()
    );
    println!(
        "  serverless billing: {} invocations, {} cold starts, ${:.6}",
        srv.lambda_invocations, srv.lambda_cold_starts, srv.lambda_cost_usd
    );
    let (li, ls) = (
        inst.final_val_loss().unwrap_or(f32::NAN),
        srv.final_val_loss().unwrap_or(f32::NAN),
    );
    println!(
        "  same math check: |delta val_loss| = {:.6} (offload must not change gradients)",
        (li - ls).abs()
    );

    // ---------------- modeled cloud scale (fig 3) -------------------
    println!("\n[2/2] modeled cloud scale (VGG-11, calibrated perfmodel):");
    for (peers, batch) in [(4usize, 64usize), (4, 1024), (12, 64), (12, 1024)] {
        let c = cloud_exps::fig3_cell(PaperModel::Vgg11, peers, batch)?;
        println!(
            "  peers={peers:<2} batch={batch:<5} serverless {:>7.1}s vs instance {:>7.1}s -> {:.2}% improvement",
            c.serverless_s,
            c.instance_s,
            c.improvement * 100.0
        );
    }
    println!("\npaper headline: 97.34% at 4 peers / batch 64");
    Ok(())
}
