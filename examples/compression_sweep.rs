//! Gradient-compression sweep (fig 5's mechanism, end to end): run the
//! same cluster with no compression, QSGD at several levels, and top-k,
//! and report wire bytes, codec speed, and the effect on convergence.
//!
//!     cargo run --release --example compression_sweep

use std::time::Instant;

use p2pless::compress::codec_for;
use p2pless::config::{Compression, SyncMode, TrainConfig};
use p2pless::coordinator::Cluster;
use p2pless::util::Rng;

fn main() -> anyhow::Result<()> {
    // ---- codec microcomparison on a MobileNet-sized gradient --------
    let n = 2_500_000usize;
    let mut rng = Rng::seed_from_u64(3);
    let v: Vec<f32> = (0..n).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();
    println!("codec comparison on a {n}-element gradient ({} MB raw):", n * 4 / 1_000_000);
    println!(
        "{:<12} {:>10} {:>8} {:>12} {:>12} {:>10}",
        "codec", "wire", "ratio", "encode", "decode", "rel. err"
    );
    for spec in ["none", "qsgd:4", "qsgd:16", "qsgd:64", "topk:0.01", "topk:0.1"] {
        let compression = Compression::parse(spec)?;
        let codec = codec_for(compression, 7);
        let t0 = Instant::now();
        let wire = codec.encode(&v)?;
        let enc = t0.elapsed();
        let t0 = Instant::now();
        let out = codec.decode(&wire)?;
        let dec = t0.elapsed();
        let err_num: f64 = v
            .iter()
            .zip(&out)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let norm: f64 = v.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
        println!(
            "{:<12} {:>8} KB {:>7.2}x {:>12?} {:>12?} {:>9.4}",
            spec,
            wire.len() / 1000,
            (n * 4) as f64 / wire.len() as f64,
            enc,
            dec,
            err_num / norm
        );
    }

    // ---- end-to-end effect on training ------------------------------
    println!("\nend-to-end training with each codec (2 peers, 2 epochs):");
    let mut engine = None;
    for spec in ["none", "qsgd:16", "topk:0.1"] {
        let cfg = TrainConfig {
            model: "mini_squeezenet".into(),
            dataset: "mnist".into(),
            peers: 2,
            batch_size: 16,
            epochs: 2,
            train_samples: 2 * 16 * 4,
            val_samples: 64,
            sync: SyncMode::Synchronous,
            compression: Compression::parse(spec)?,
            ..Default::default()
        };
        let cluster = match &engine {
            None => {
                let c = Cluster::new(cfg)?;
                engine = Some(c.engine());
                c
            }
            Some(e) => Cluster::with_engine(cfg, e.clone())?,
        };
        let rep = cluster.run()?;
        let sent: usize = rep.peers.iter().flat_map(|p| p.sent_bytes.iter()).sum();
        println!(
            "  {:<10} wire sent {:>9} bytes  final val_loss {:?}",
            spec,
            sent,
            rep.final_val_loss()
        );
    }
    println!("\npaper fig 5: QSGD cuts send+receive time across all batch sizes");
    Ok(())
}
