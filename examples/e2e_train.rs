//! END-TO-END VALIDATION DRIVER (see DESIGN.md / EXPERIMENTS.md).
//!
//! Trains the mini-VGG CNN across 4 peers for several hundred
//! per-peer gradient steps on the synthetic MNIST corpus, with QSGD
//! compression on the exchange path and convergence detection armed —
//! proving all layers compose:
//!
//!   L1 Pallas matmul kernels (inside every grad artifact)
//!   L2 JAX model (AOT HLO, executed via PJRT from rust)
//!   L3 rust coordinator (peers, broker, barrier, QSGD wire, SGD)
//!
//! Prints the full loss/accuracy curve; the run is recorded in
//! EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example e2e_train

use std::time::Instant;

use p2pless::config::{Backend, Compression, SyncMode, TrainConfig};
use p2pless::coordinator::Cluster;

fn main() -> anyhow::Result<()> {
    let epochs = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20usize);
    let config = TrainConfig {
        model: "mini_vgg".into(),
        dataset: "mnist".into(),
        peers: 4,
        batch_size: 16,
        epochs,
        lr: 0.03,
        train_samples: 4 * 16 * 6, // 6 batches/peer/epoch
        val_samples: 256,
        backend: Backend::Instance,
        sync: SyncMode::Synchronous,
        compression: Compression::Qsgd { s: 127 },
        early_stop_patience: 8,
        plateau_patience: 4,
        ..Default::default()
    };
    let steps_per_epoch = config.train_samples / config.peers / config.batch_size;
    println!(
        "e2e: {} on {} | {} peers x {} epochs x {} batches/peer = {} peer gradient steps",
        config.model,
        config.dataset,
        config.peers,
        config.epochs,
        steps_per_epoch,
        config.peers * config.epochs * steps_per_epoch,
    );
    println!(
        "batch={} lr={} compression={} early_stop={} plateau={}",
        config.batch_size,
        config.lr,
        config.compression.to_spec(),
        config.early_stop_patience,
        config.plateau_patience
    );

    let t0 = Instant::now();
    let report = Cluster::new(config)?.run()?;

    println!("\nepoch  val_loss  val_acc  mean_train_loss");
    for (i, (e, loss, acc)) in report.val_curve.iter().enumerate() {
        let train: Vec<f32> = report
            .peers
            .iter()
            .filter_map(|p| p.train_loss.get(i).copied())
            .collect();
        let mean_train = train.iter().sum::<f32>() / train.len().max(1) as f32;
        println!("{e:>5}  {loss:>8.4}  {acc:>7.3}  {mean_train:>15.4}");
    }

    println!("\nper-stage wall (all peers):");
    for (stage, s) in &report.stages {
        if s.count > 0 {
            println!(
                "  {:<22} n={:<4} total {:>10.3?}  mean {:>10.3?}",
                stage.to_string(),
                s.count,
                s.total_wall,
                s.mean_wall()
            );
        }
    }
    println!(
        "\nbroker: {} msgs / {:.1} MB wire",
        report.broker_msgs,
        report.broker_bytes as f64 / 1e6
    );
    println!("total wall: {:?}", t0.elapsed());

    // the check that makes this a validation driver, not a demo:
    let first = report.val_curve.first().map(|v| v.1).unwrap_or(f32::NAN);
    let last = report.val_curve.last().map(|v| v.1).unwrap_or(f32::NAN);
    let acc = report.final_val_acc().unwrap_or(0.0);
    println!("\nval_loss {first:.4} -> {last:.4}; final val_acc {acc:.3}");
    anyhow::ensure!(last < first, "training must reduce validation loss");
    anyhow::ensure!(acc > 0.2, "accuracy must beat chance (0.1) clearly, got {acc}");
    println!("e2e PASS: all three layers compose and the model learns");
    Ok(())
}
