//! Quickstart: train a small CNN across 4 peers (instance backend,
//! synchronous exchange) on synthetic MNIST and print the loss curve.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This exercises the full stack: synthetic data -> partitioning -> per
//! -batch PJRT gradients (Pallas matmul inside) -> broker gradient
//! exchange -> averaging -> SGD update -> convergence detection.

use p2pless::config::{Backend, SyncMode, TrainConfig};
use p2pless::coordinator::Cluster;

fn main() -> anyhow::Result<()> {
    let config = TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 4,
        batch_size: 16,
        epochs: 3,
        lr: 0.05,
        train_samples: 512,
        val_samples: 256,
        backend: Backend::Instance,
        sync: SyncMode::Synchronous,
        ..Default::default()
    };
    println!("p2pless quickstart: {} on {}", config.model, config.dataset);
    println!(
        "peers={} batch={} epochs={} backend={}",
        config.peers,
        config.batch_size,
        config.epochs,
        config.backend.name()
    );

    let report = Cluster::new(config)?.run()?;

    println!("\nepoch  val_loss  val_acc");
    for (e, loss, acc) in &report.val_curve {
        println!("{e:>5}  {loss:>8.4}  {acc:>7.3}");
    }
    println!("\nper-stage wall time (all peers):");
    for (stage, s) in &report.stages {
        if s.count > 0 {
            println!(
                "  {:<22} total {:>9.3?}  mean {:>9.3?}  cpu {:>5.1}%",
                stage.to_string(),
                s.total_wall,
                s.mean_wall(),
                s.mean_cpu_pct
            );
        }
    }
    println!(
        "\nbroker: {} msgs, {} bytes; wall {:?}",
        report.broker_msgs, report.broker_bytes, report.wall
    );
    let first = report.peers[0].train_loss.first().copied().unwrap_or(f32::NAN);
    let last = report.mean_train_loss_last_epoch().unwrap_or(f32::NAN);
    println!("train loss: first epoch {first:.4} -> last epoch {last:.4}");
    Ok(())
}
